//! Phase-span recording: where a node's round actually went.
//!
//! The engine worker loops carve each round into five monotonic-clock
//! spans — [`Phase::Wait`] (barrier / admission / TCP watermark
//! blocking), [`Phase::Drain`] (inbox drain + payload decode),
//! [`Phase::Compute`] (local step / resolvent), [`Phase::Encode`]
//! (outgoing state + wire compression), and [`Phase::Send`] (handing
//! frames to the transport). A [`PhaseSpans`] accumulator collects
//! microseconds per phase between telemetry flushes; the engine folds
//! the totals into the schema-v2 [`TelemetryRow`] phase fields.
//!
//! The recorder only exists when telemetry is enabled (it lives inside
//! the engine's per-node `Option<NodeTelemetry>`), so span recording
//! costs nothing on the hot path of an uninstrumented run. The five
//! spans deliberately do not have to sum to `wall_micros`: engine
//! bookkeeping between spans (cost accounting, row flushing) is left
//! unattributed rather than misattributed.
//!
//! [`TelemetryRow`]: super::schema::TelemetryRow

use std::time::{Duration, Instant};

/// One of the five per-round phases a worker attributes time to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    /// Blocked on peers: sync barriers, async admission, TCP watermark
    /// waits inside the port drain.
    Wait,
    /// Draining the inbox and decoding neighbor payloads.
    Drain,
    /// The node's local step / resolvent evaluation.
    Compute,
    /// Encoding outgoing state and compressing it for the wire.
    Encode,
    /// Handing frames (and the end-of-round watermark) to the transport.
    Send,
}

const PHASES: usize = 5;

impl Phase {
    fn idx(self) -> usize {
        match self {
            Phase::Wait => 0,
            Phase::Drain => 1,
            Phase::Compute => 2,
            Phase::Encode => 3,
            Phase::Send => 4,
        }
    }
}

/// Per-phase microsecond accumulator for one node, reset at every
/// telemetry flush (i.e. once per reported round).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct PhaseSpans {
    micros: [u64; PHASES],
}

impl PhaseSpans {
    pub fn new() -> PhaseSpans {
        PhaseSpans::default()
    }

    /// Attribute `d` to `phase`.
    pub fn record(&mut self, phase: Phase, d: Duration) {
        self.add_micros(phase, d.as_micros() as u64);
    }

    /// Attribute raw microseconds to `phase` (saturating).
    pub fn add_micros(&mut self, phase: Phase, micros: u64) {
        let slot = &mut self.micros[phase.idx()];
        *slot = slot.saturating_add(micros);
    }

    /// Microseconds accumulated in `phase` since the last [`take`].
    ///
    /// [`take`]: PhaseSpans::take
    pub fn get(&self, phase: Phase) -> u64 {
        self.micros[phase.idx()]
    }

    /// Return the accumulated spans and reset to zero.
    pub fn take(&mut self) -> PhaseSpans {
        std::mem::take(self)
    }
}

/// A restartable stopwatch for carving a worker loop into phase spans:
/// each [`lap`] attributes the time since the previous lap (or start)
/// and restarts the clock.
///
/// [`lap`]: SpanTimer::lap
#[derive(Debug)]
pub struct SpanTimer {
    t0: Instant,
}

impl SpanTimer {
    pub fn start() -> SpanTimer {
        SpanTimer { t0: Instant::now() }
    }

    /// Restart the clock without attributing the elapsed time (used
    /// when the preceding region is deliberately unattributed).
    pub fn reset(&mut self) {
        self.t0 = Instant::now();
    }

    /// Attribute the time since the last lap to `phase` and restart.
    pub fn lap(&mut self, spans: &mut PhaseSpans, phase: Phase) {
        let now = Instant::now();
        spans.record(phase, now.duration_since(self.t0));
        self.t0 = now;
    }

    /// Like [`lap`], but `blocked_micros` of the elapsed time is
    /// attributed to [`Phase::Wait`] and only the remainder to `phase`.
    /// Used for TCP drains, where the port reports how long it sat
    /// blocked on peer watermarks inside the drain call.
    ///
    /// [`lap`]: SpanTimer::lap
    pub fn lap_split(&mut self, spans: &mut PhaseSpans, phase: Phase, blocked_micros: u64) {
        let now = Instant::now();
        let total = now.duration_since(self.t0).as_micros() as u64;
        let blocked = blocked_micros.min(total);
        spans.add_micros(Phase::Wait, blocked);
        spans.add_micros(phase, total - blocked);
        self.t0 = now;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spans_accumulate_and_take_resets() {
        let mut s = PhaseSpans::new();
        s.add_micros(Phase::Compute, 100);
        s.add_micros(Phase::Compute, 50);
        s.record(Phase::Wait, Duration::from_micros(7));
        assert_eq!(s.get(Phase::Compute), 150);
        assert_eq!(s.get(Phase::Wait), 7);
        assert_eq!(s.get(Phase::Send), 0);
        let taken = s.take();
        assert_eq!(taken.get(Phase::Compute), 150);
        assert_eq!(s, PhaseSpans::new(), "take resets the accumulator");
    }

    #[test]
    fn add_saturates_instead_of_wrapping() {
        let mut s = PhaseSpans::new();
        s.add_micros(Phase::Drain, u64::MAX - 1);
        s.add_micros(Phase::Drain, 10);
        assert_eq!(s.get(Phase::Drain), u64::MAX);
    }

    #[test]
    fn timer_laps_attribute_nonnegative_time() {
        let mut s = PhaseSpans::new();
        let mut t = SpanTimer::start();
        std::thread::sleep(Duration::from_millis(2));
        t.lap(&mut s, Phase::Compute);
        t.lap(&mut s, Phase::Send);
        assert!(s.get(Phase::Compute) >= 1_000, "slept ~2ms: {:?}", s);
        // the second lap measures only time since the first
        assert!(s.get(Phase::Send) < s.get(Phase::Compute));
    }

    #[test]
    fn lap_split_clamps_blocked_time_to_the_lap() {
        let mut s = PhaseSpans::new();
        let mut t = SpanTimer::start();
        std::thread::sleep(Duration::from_millis(2));
        // port claims to have blocked longer than the whole lap — the
        // split clamps, so drain never goes negative (it lands at 0)
        t.lap_split(&mut s, Phase::Drain, u64::MAX);
        assert!(s.get(Phase::Wait) >= 1_000);
        assert_eq!(s.get(Phase::Wait) + s.get(Phase::Drain), s.get(Phase::Wait));
    }
}
