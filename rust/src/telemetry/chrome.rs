//! Chrome trace-event export (`dsba trace export --format chrome`).
//!
//! Turns a telemetry JSONL stream into the Trace Event Format JSON that
//! `chrome://tracing` and [Perfetto](https://ui.perfetto.dev) load: an
//! array of event objects. Each node becomes one "process" (`pid` =
//! topology index); its v2 phase spans become back-to-back complete
//! (`"ph":"X"`) events per round, and every control-plane event line
//! becomes an instant (`"ph":"i"`) event.
//!
//! Two clocks meet here. Rows carry per-phase *durations*, not start
//! times, so each node's spans are laid out on a cumulative per-node
//! cursor starting at zero — faithful to where a node's time went,
//! not to fleet-wide simultaneity. Control events carry real monotonic
//! timestamps from the writer epoch and are exported as-is.

use super::events::RunEvent;
use super::report::parse_stream_lenient;
use super::schema::TelemetryRow;
use crate::util::json::Json;
use std::collections::BTreeMap;

/// One complete ("X") trace event: a named span on a node's timeline.
fn complete_event(name: &str, node: u32, ts: u64, dur: u64, round: u64) -> Json {
    Json::from_pairs(vec![
        ("ph", Json::Str("X".into())),
        ("name", Json::Str(name.into())),
        ("cat", Json::Str("phase".into())),
        ("pid", Json::Num(node as f64)),
        ("tid", Json::Num(0.0)),
        ("ts", Json::Num(ts as f64)),
        ("dur", Json::Num(dur as f64)),
        ("args", Json::from_pairs(vec![("round", Json::Num(round as f64))])),
    ])
}

/// One instant ("i") trace event for a control-plane event line.
fn instant_event(ev: &RunEvent) -> Json {
    let mut args: Vec<(&str, Json)> = Vec::new();
    if let Some(p) = ev.peer {
        args.push(("peer", Json::Num(p as f64)));
    }
    if let Some(r) = ev.round {
        args.push(("round", Json::Num(r as f64)));
    }
    if let Some(s) = ev.seq {
        args.push(("seq", Json::Num(s as f64)));
    }
    if !ev.detail.is_empty() {
        args.push(("detail", Json::Str(ev.detail.clone())));
    }
    Json::from_pairs(vec![
        ("ph", Json::Str("i".into())),
        ("name", Json::Str(ev.kind.name().into())),
        ("cat", Json::Str("control".into())),
        ("pid", Json::Num(ev.node.unwrap_or(0) as f64)),
        ("tid", Json::Num(0.0)),
        ("ts", Json::Num(ev.ts_micros as f64)),
        // process scope when the event names a node, global otherwise
        ("s", Json::Str(if ev.node.is_some() { "p" } else { "g" }.into())),
        ("args", Json::from_pairs(args)),
    ])
}

/// The five phase spans of one row, in timeline order.
fn phase_spans(r: &TelemetryRow) -> [(&'static str, u64); 5] {
    [
        ("wait", r.wait_micros),
        ("drain", r.drain_micros),
        ("compute", r.compute_micros),
        ("encode", r.encode_micros),
        ("send", r.send_micros),
    ]
}

/// Export a telemetry stream as a Chrome trace-event JSON array.
///
/// Tolerant like `dsba report`: unknown `kind` lines are skipped and a
/// truncated final line is ignored. Fails only on a malformed stream or
/// one with nothing to draw.
pub fn chrome_trace(text: &str) -> Result<Json, String> {
    let ps = parse_stream_lenient(text)?;
    if ps.rows.is_empty() && ps.events.is_empty() {
        return Err("telemetry stream has no rows or events to trace".to_string());
    }
    let mut out: Vec<Json> = Vec::new();
    let mut cursor: BTreeMap<u32, u64> = BTreeMap::new();
    for r in &ps.rows {
        let start = cursor.entry(r.node).or_insert(0);
        let mut t = *start;
        for (name, dur) in phase_spans(r) {
            if dur == 0 {
                continue;
            }
            out.push(complete_event(name, r.node, t, dur, r.round));
            t += dur;
        }
        // advance by at least the row's wall time so successive rounds
        // never overlap, even when the spans under-attribute
        let attributed = t - *start;
        *start += r.wall_micros.max(attributed);
    }
    for ev in &ps.events {
        out.push(instant_event(ev));
    }
    Ok(Json::Arr(out))
}

#[cfg(test)]
mod tests {
    use super::super::events::{EventKind, RunEvent};
    use super::super::schema::{TelemetryRow, TelemetrySummary};
    use super::*;
    use crate::util::json::parse;

    fn row(round: u64, node: u32) -> TelemetryRow {
        TelemetryRow {
            round,
            node,
            residual: 0.5,
            wall_micros: 1000,
            wait_micros: 300,
            drain_micros: 100,
            compute_micros: 500,
            encode_micros: 50,
            send_micros: 50,
            ..TelemetryRow::default()
        }
    }

    #[test]
    fn export_is_a_valid_trace_event_array() {
        let text = format!(
            "{}\n{}\n{}\n{}\n",
            row(0, 0).to_json_line(),
            row(0, 1).to_json_line(),
            RunEvent::new(EventKind::NackSent).node(0).peer(1).seq(3).to_json_line(),
            TelemetrySummary { rows_written: 2, rows_dropped: 0 }.to_json_line(),
        );
        let trace = chrome_trace(&text).unwrap();
        // the document is an array, and reparses from its serialization
        let doc = parse(&trace.to_string()).unwrap();
        let events = doc.as_arr().expect("trace-event JSON is an array");
        // 5 phases x 2 rows + 1 instant
        assert_eq!(events.len(), 11);
        for ev in events {
            let ph = ev.get("ph").and_then(Json::as_str).unwrap();
            assert!(ph == "X" || ph == "i", "unexpected ph {ph:?}");
            assert!(ev.get("ts").and_then(Json::as_f64).is_some());
            assert!(ev.get("pid").and_then(Json::as_f64).is_some());
            if ph == "X" {
                assert!(ev.get("dur").and_then(Json::as_f64).is_some());
            }
        }
        let instants: Vec<&Json> = events
            .iter()
            .filter(|e| e.get("ph").and_then(Json::as_str) == Some("i"))
            .collect();
        assert_eq!(instants.len(), 1);
        assert_eq!(instants[0].get("name").and_then(Json::as_str), Some("nack-sent"));
        assert_eq!(
            instants[0].get("args").unwrap().get("peer").and_then(Json::as_usize),
            Some(1)
        );
    }

    #[test]
    fn rounds_lay_out_back_to_back_per_node() {
        let text = format!("{}\n{}\n", row(0, 0).to_json_line(), row(1, 0).to_json_line());
        let trace = chrome_trace(&text).unwrap();
        let events = trace.as_arr().unwrap();
        // round 0 spans start at 0; round 1's first span starts at
        // wall_micros (1000), not at the 1000-μs attributed sum's end
        let first_round1 = events
            .iter()
            .find(|e| {
                e.get("args").and_then(|a| a.get("round")).and_then(Json::as_usize)
                    == Some(1)
            })
            .unwrap();
        assert_eq!(first_round1.get("ts").and_then(Json::as_usize), Some(1000));
    }

    #[test]
    fn zero_span_rows_still_export_their_events() {
        // v1-shaped rows (no spans) plus one control event: instants only
        let mut r = row(0, 0);
        r.wait_micros = 0;
        r.drain_micros = 0;
        r.compute_micros = 0;
        r.encode_micros = 0;
        r.send_micros = 0;
        let text = format!(
            "{}\n{}\n",
            r.to_json_line(),
            RunEvent::new(EventKind::NodeKill).node(0).round(2).to_json_line()
        );
        let trace = chrome_trace(&text).unwrap();
        assert_eq!(trace.as_arr().unwrap().len(), 1, "no spans, one instant");
        assert!(chrome_trace("").is_err(), "nothing to draw is an error");
    }
}
