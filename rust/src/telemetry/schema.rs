//! Versioned per-round, per-node telemetry row schema.
//!
//! One [`TelemetryRow`] is one JSONL line in the run's telemetry stream:
//! which node finished which round, how far its iterate moved, what it
//! paid in communication, how long the step took, and what the reliable
//! link layer had to do to keep the round lossless (retransmits, dedups,
//! injected faults). The schema is versioned through the `v` key so
//! downstream consumers can reject rows they do not understand;
//! [`validate_jsonl`] is the machine check behind `dsba telemetry-check`
//! and `make smoke`.

use crate::util::json::{parse, Json};

/// Schema version stamped into every row's `v` key.
pub const TELEMETRY_SCHEMA_VERSION: u64 = 1;

/// One per-round, per-node telemetry record.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct TelemetryRow {
    /// Round the node just completed.
    pub round: u64,
    /// Global topology index of the reporting node.
    pub node: u32,
    /// `||x_t - x_{t-1}||_2` of the node's local iterate.
    pub residual: f64,
    /// DOUBLE-equivalents emitted by this node this round (paper `C_n^t`).
    pub doubles_sent: f64,
    /// DOUBLE-equivalents delivered to this node this round.
    pub doubles_recv: f64,
    /// Encoded payload bytes this node put on the wire this round.
    pub bytes_on_wire: u64,
    /// Wall-clock duration of the node's round, in microseconds.
    pub wall_micros: u64,
    /// Messages drained from the node's inbox this round.
    pub queue_depth: u64,
    /// Staleness (rounds) of the oldest neighbor data consumed this
    /// round; always 0 under the sync clock.
    pub staleness: u64,
    /// Admission-poll stalls this node has accumulated (async clock).
    pub stalls: u64,
    /// Link-layer frames this node's ports re-sent after a NACK.
    pub retransmits: u64,
    /// Duplicate link-layer frames this node's ports discarded.
    pub dedups: u64,
    /// Frames the fault injector dropped on this node's outgoing links.
    pub drops_injected: u64,
    /// Frames the fault injector duplicated on this node's outgoing links.
    pub dups_injected: u64,
}

impl TelemetryRow {
    /// Serialize as one compact JSONL line (no trailing newline).
    pub fn to_json_line(&self) -> String {
        Json::from_pairs(vec![
            ("v", Json::Num(TELEMETRY_SCHEMA_VERSION as f64)),
            ("round", Json::Num(self.round as f64)),
            ("node", Json::Num(self.node as f64)),
            ("residual", Json::Num(self.residual)),
            ("doubles_sent", Json::Num(self.doubles_sent)),
            ("doubles_recv", Json::Num(self.doubles_recv)),
            ("bytes_on_wire", Json::Num(self.bytes_on_wire as f64)),
            ("wall_micros", Json::Num(self.wall_micros as f64)),
            ("queue_depth", Json::Num(self.queue_depth as f64)),
            ("staleness", Json::Num(self.staleness as f64)),
            ("stalls", Json::Num(self.stalls as f64)),
            ("retransmits", Json::Num(self.retransmits as f64)),
            ("dedups", Json::Num(self.dedups as f64)),
            ("drops_injected", Json::Num(self.drops_injected as f64)),
            ("dups_injected", Json::Num(self.dups_injected as f64)),
        ])
        .to_string()
    }

    /// Parse and validate one JSONL line (inverse of [`to_json_line`]
    /// on well-formed rows; strict about version and required keys).
    ///
    /// [`to_json_line`]: TelemetryRow::to_json_line
    pub fn from_json_line(line: &str) -> Result<TelemetryRow, String> {
        let v = parse(line.trim())?;
        let version = req_u64(&v, "v")?;
        if version != TELEMETRY_SCHEMA_VERSION {
            return Err(format!(
                "unsupported telemetry schema v{version} (expected v{TELEMETRY_SCHEMA_VERSION})"
            ));
        }
        let node = req_u64(&v, "node")?;
        if node > u32::MAX as u64 {
            return Err(format!("node {node} out of range"));
        }
        Ok(TelemetryRow {
            round: req_u64(&v, "round")?,
            node: node as u32,
            residual: req_f64(&v, "residual")?,
            doubles_sent: req_f64(&v, "doubles_sent")?,
            doubles_recv: req_f64(&v, "doubles_recv")?,
            bytes_on_wire: req_u64(&v, "bytes_on_wire")?,
            wall_micros: req_u64(&v, "wall_micros")?,
            queue_depth: req_u64(&v, "queue_depth")?,
            staleness: req_u64(&v, "staleness")?,
            stalls: req_u64(&v, "stalls")?,
            retransmits: req_u64(&v, "retransmits")?,
            dedups: req_u64(&v, "dedups")?,
            drops_injected: req_u64(&v, "drops_injected")?,
            dups_injected: req_u64(&v, "dups_injected")?,
        })
    }
}

fn req_f64(v: &Json, key: &str) -> Result<f64, String> {
    v.get(key)
        .and_then(Json::as_f64)
        .ok_or_else(|| format!("missing or non-numeric key {key:?}"))
}

fn req_u64(v: &Json, key: &str) -> Result<u64, String> {
    let n = req_f64(v, key)?;
    if n < 0.0 || n != n.trunc() {
        return Err(format!("key {key:?} must be a non-negative integer, got {n}"));
    }
    Ok(n as u64)
}

/// Validate a whole telemetry stream: every non-empty line must parse
/// as a schema-v1 row. Returns the number of rows on success, or the
/// first offending line (1-based) and its error.
pub fn validate_jsonl(text: &str) -> Result<usize, String> {
    let mut rows = 0;
    for (i, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        TelemetryRow::from_json_line(line)
            .map_err(|e| format!("line {}: {e}", i + 1))?;
        rows += 1;
    }
    Ok(rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> TelemetryRow {
        TelemetryRow {
            round: 12,
            node: 3,
            residual: 0.125,
            doubles_sent: 40.0,
            doubles_recv: 80.5,
            bytes_on_wire: 356,
            wall_micros: 1812,
            queue_depth: 2,
            staleness: 1,
            stalls: 4,
            retransmits: 1,
            dedups: 2,
            drops_injected: 1,
            dups_injected: 2,
        }
    }

    #[test]
    fn row_roundtrips_through_jsonl() {
        let row = sample();
        let line = row.to_json_line();
        assert!(!line.contains('\n'), "a row must be a single line");
        assert_eq!(TelemetryRow::from_json_line(&line).unwrap(), row);
    }

    #[test]
    fn parse_rejects_bad_rows() {
        assert!(TelemetryRow::from_json_line("not json").is_err());
        assert!(TelemetryRow::from_json_line("{}").is_err(), "missing keys");
        // wrong version
        let line = sample().to_json_line().replace("\"v\":1", "\"v\":99");
        assert!(TelemetryRow::from_json_line(&line).is_err());
        // non-integer integer field
        let line = sample().to_json_line().replace("\"round\":12", "\"round\":1.5");
        assert!(TelemetryRow::from_json_line(&line).is_err());
        // negative counter
        let line = sample().to_json_line().replace("\"dedups\":2", "\"dedups\":-2");
        assert!(TelemetryRow::from_json_line(&line).is_err());
    }

    #[test]
    fn validate_jsonl_counts_rows_and_names_bad_lines() {
        let good = format!("{}\n\n{}\n", sample().to_json_line(), sample().to_json_line());
        assert_eq!(validate_jsonl(&good), Ok(2));
        assert_eq!(validate_jsonl(""), Ok(0));
        let bad = format!("{}\ngarbage\n", sample().to_json_line());
        let err = validate_jsonl(&bad).unwrap_err();
        assert!(err.starts_with("line 2:"), "{err}");
    }
}
