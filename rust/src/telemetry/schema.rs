//! Versioned per-round, per-node telemetry row schema.
//!
//! One [`TelemetryRow`] is one JSONL line in the run's telemetry stream:
//! which node finished which round, how far its iterate moved, what it
//! paid in communication, how long the step took and where that time
//! went (the v2 phase spans), and what the reliable link layer had to do
//! to keep the round lossless (retransmits, dedups, injected faults).
//! The schema is versioned through the `v` key so downstream consumers
//! can reject rows they do not understand; [`validate_jsonl`] is the
//! machine check behind `dsba telemetry-check` and `make smoke`.
//!
//! # Versions
//!
//! - **v1** (PR 8): the base row — residual, communication cost, wall
//!   time, queue depth, staleness, and link counters.
//! - **v2** (this schema): adds five monotonic-clock **phase spans** in
//!   microseconds (`wait`, `drain`, `compute`, `encode`, `send` — see
//!   [`TelemetryRow::wait_micros`] and friends) and a trailing
//!   [`TelemetrySummary`] line carrying the writer's written/dropped row
//!   counts, so silent row loss is visible after the process exits.
//!
//! v1 rows still parse (their phase spans read as zero). Versions this
//! build does not understand are rejected with a named error, never a
//! panic.

use super::events::RunEvent;
use crate::util::json::{parse, Json};

/// Schema version stamped into every row's `v` key.
pub const TELEMETRY_SCHEMA_VERSION: u64 = 2;

/// Oldest schema version this build still parses.
pub const TELEMETRY_SCHEMA_MIN_VERSION: u64 = 1;

/// One per-round, per-node telemetry record.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct TelemetryRow {
    /// Round the node just completed.
    pub round: u64,
    /// Global topology index of the reporting node.
    pub node: u32,
    /// `||x_t - x_{t-1}||_2` of the node's local iterate.
    pub residual: f64,
    /// DOUBLE-equivalents emitted by this node this round (paper `C_n^t`).
    pub doubles_sent: f64,
    /// DOUBLE-equivalents delivered to this node this round.
    pub doubles_recv: f64,
    /// Encoded payload bytes this node put on the wire this round.
    pub bytes_on_wire: u64,
    /// Wall-clock duration of the node's round, in microseconds.
    pub wall_micros: u64,
    /// Messages drained from the node's inbox this round.
    pub queue_depth: u64,
    /// Staleness (rounds) of the oldest neighbor data consumed this
    /// round; always 0 under the sync clock.
    pub staleness: u64,
    /// Admission-poll stalls this node has accumulated (async clock).
    pub stalls: u64,
    /// Link-layer frames this node's ports re-sent after a NACK.
    pub retransmits: u64,
    /// Duplicate link-layer frames this node's ports discarded.
    pub dedups: u64,
    /// Frames the fault injector dropped on this node's outgoing links.
    pub drops_injected: u64,
    /// Frames the fault injector duplicated on this node's outgoing links.
    pub dups_injected: u64,
    /// Microseconds blocked waiting on peers this round: barrier waits
    /// under the sync clock, admission stalls under the async clock, and
    /// TCP watermark waits inside the port drain (v2; 0 in v1 rows).
    pub wait_micros: u64,
    /// Microseconds draining the inbox and decoding neighbor payloads
    /// (v2; 0 in v1 rows).
    pub drain_micros: u64,
    /// Microseconds in the node's local step / resolvent evaluation
    /// (v2; 0 in v1 rows).
    pub compute_micros: u64,
    /// Microseconds encoding the outgoing state and compressing it for
    /// the wire (v2; 0 in v1 rows).
    pub encode_micros: u64,
    /// Microseconds handing frames to the transport, including the
    /// end-of-round watermark (v2; 0 in v1 rows).
    pub send_micros: u64,
}

impl TelemetryRow {
    /// Serialize as one compact JSONL line (no trailing newline).
    pub fn to_json_line(&self) -> String {
        Json::from_pairs(vec![
            ("v", Json::Num(TELEMETRY_SCHEMA_VERSION as f64)),
            ("round", Json::Num(self.round as f64)),
            ("node", Json::Num(self.node as f64)),
            ("residual", Json::Num(self.residual)),
            ("doubles_sent", Json::Num(self.doubles_sent)),
            ("doubles_recv", Json::Num(self.doubles_recv)),
            ("bytes_on_wire", Json::Num(self.bytes_on_wire as f64)),
            ("wall_micros", Json::Num(self.wall_micros as f64)),
            ("queue_depth", Json::Num(self.queue_depth as f64)),
            ("staleness", Json::Num(self.staleness as f64)),
            ("stalls", Json::Num(self.stalls as f64)),
            ("retransmits", Json::Num(self.retransmits as f64)),
            ("dedups", Json::Num(self.dedups as f64)),
            ("drops_injected", Json::Num(self.drops_injected as f64)),
            ("dups_injected", Json::Num(self.dups_injected as f64)),
            ("wait_micros", Json::Num(self.wait_micros as f64)),
            ("drain_micros", Json::Num(self.drain_micros as f64)),
            ("compute_micros", Json::Num(self.compute_micros as f64)),
            ("encode_micros", Json::Num(self.encode_micros as f64)),
            ("send_micros", Json::Num(self.send_micros as f64)),
        ])
        .to_string()
    }

    /// Parse and validate one JSONL line (inverse of [`to_json_line`]
    /// on well-formed rows; strict about version and required keys).
    /// Accepts v1 rows — their phase spans read as zero.
    ///
    /// [`to_json_line`]: TelemetryRow::to_json_line
    pub fn from_json_line(line: &str) -> Result<TelemetryRow, String> {
        let v = parse(line.trim())?;
        TelemetryRow::from_json(&v)
    }

    fn from_json(v: &Json) -> Result<TelemetryRow, String> {
        let version = check_version(v)?;
        let node = req_u64(v, "node")?;
        if node > u32::MAX as u64 {
            return Err(format!("node {node} out of range"));
        }
        // v2 rows must carry the phase spans; v1 rows predate them
        let phase = |key: &str| -> Result<u64, String> {
            if version >= 2 {
                req_u64(v, key)
            } else {
                Ok(0)
            }
        };
        Ok(TelemetryRow {
            round: req_u64(v, "round")?,
            node: node as u32,
            residual: req_f64(v, "residual")?,
            doubles_sent: req_f64(v, "doubles_sent")?,
            doubles_recv: req_f64(v, "doubles_recv")?,
            bytes_on_wire: req_u64(v, "bytes_on_wire")?,
            wall_micros: req_u64(v, "wall_micros")?,
            queue_depth: req_u64(v, "queue_depth")?,
            staleness: req_u64(v, "staleness")?,
            stalls: req_u64(v, "stalls")?,
            retransmits: req_u64(v, "retransmits")?,
            dedups: req_u64(v, "dedups")?,
            drops_injected: req_u64(v, "drops_injected")?,
            dups_injected: req_u64(v, "dups_injected")?,
            wait_micros: phase("wait_micros")?,
            drain_micros: phase("drain_micros")?,
            compute_micros: phase("compute_micros")?,
            encode_micros: phase("encode_micros")?,
            send_micros: phase("send_micros")?,
        })
    }
}

/// The trailing stream-summary line the writer appends at shutdown
/// (v2): how many rows made it to disk and how many the wait-free
/// channel had to drop. Lets `telemetry-check` and `report` expose
/// silent row loss after the process is gone.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct TelemetrySummary {
    /// Data rows the writer thread persisted.
    pub rows_written: u64,
    /// Rows dropped because the channel was full.
    pub rows_dropped: u64,
}

impl TelemetrySummary {
    /// Serialize as one compact JSONL line (no trailing newline).
    pub fn to_json_line(&self) -> String {
        Json::from_pairs(vec![
            ("v", Json::Num(TELEMETRY_SCHEMA_VERSION as f64)),
            ("kind", Json::Str("summary".into())),
            ("rows_written", Json::Num(self.rows_written as f64)),
            ("rows_dropped", Json::Num(self.rows_dropped as f64)),
        ])
        .to_string()
    }

    fn from_json(v: &Json) -> Result<TelemetrySummary, String> {
        check_version(v)?;
        Ok(TelemetrySummary {
            rows_written: req_u64(v, "rows_written")?,
            rows_dropped: req_u64(v, "rows_dropped")?,
        })
    }
}

/// One parsed line of a telemetry stream: a per-round data row, the
/// trailing writer summary, or a control-plane event.
#[derive(Clone, Debug, PartialEq)]
pub enum TelemetryLine {
    Row(TelemetryRow),
    Summary(TelemetrySummary),
    Event(RunEvent),
}

impl TelemetryLine {
    /// Parse one JSONL line, dispatching on the `kind` key (absent on
    /// data rows, `"summary"` on the trailing summary, `"event"` on
    /// control-plane events). Unknown kinds are an error; readers that
    /// must survive newer streams use [`TelemetryLine::parse_lenient`].
    pub fn parse(line: &str) -> Result<TelemetryLine, String> {
        match TelemetryLine::parse_lenient(line)? {
            Some(parsed) => Ok(parsed),
            None => {
                let v = parse(line.trim())?;
                let kind = v.get("kind").and_then(Json::as_str).unwrap_or("?");
                Err(format!("unknown telemetry line kind {kind:?}"))
            }
        }
    }

    /// Like [`TelemetryLine::parse`], but a well-formed JSON object
    /// whose `kind` this build does not know returns `Ok(None)` instead
    /// of an error, so older readers replay newer streams (forward
    /// compatibility). Malformed lines still fail.
    pub fn parse_lenient(line: &str) -> Result<Option<TelemetryLine>, String> {
        let v = parse(line.trim())?;
        match v.get("kind").and_then(Json::as_str) {
            None => Ok(Some(TelemetryLine::Row(TelemetryRow::from_json(&v)?))),
            Some("summary") => {
                Ok(Some(TelemetryLine::Summary(TelemetrySummary::from_json(&v)?)))
            }
            Some("event") => Ok(Some(TelemetryLine::Event(RunEvent::from_json(&v)?))),
            Some(_) => Ok(None),
        }
    }

    /// Serialize back to the canonical JSONL line for this variant.
    pub fn to_json_line(&self) -> String {
        match self {
            TelemetryLine::Row(r) => r.to_json_line(),
            TelemetryLine::Summary(s) => s.to_json_line(),
            TelemetryLine::Event(e) => e.to_json_line(),
        }
    }
}

pub(crate) fn check_version(v: &Json) -> Result<u64, String> {
    let version = req_u64(v, "v")?;
    if !(TELEMETRY_SCHEMA_MIN_VERSION..=TELEMETRY_SCHEMA_VERSION).contains(&version) {
        return Err(format!(
            "unsupported telemetry schema v{version} (this build reads \
             v{TELEMETRY_SCHEMA_MIN_VERSION}..=v{TELEMETRY_SCHEMA_VERSION})"
        ));
    }
    Ok(version)
}

fn req_f64(v: &Json, key: &str) -> Result<f64, String> {
    let n = v
        .get(key)
        .and_then(Json::as_f64)
        .ok_or_else(|| format!("missing or non-numeric key {key:?}"))?;
    // JSON has no Inf/NaN: a non-finite value would serialize as null
    // and could never roundtrip, so reject it at the door
    if !n.is_finite() {
        return Err(format!("key {key:?} must be finite, got {n}"));
    }
    Ok(n)
}

pub(crate) fn req_u64(v: &Json, key: &str) -> Result<u64, String> {
    let n = req_f64(v, key)?;
    if n < 0.0 || n != n.trunc() {
        return Err(format!("key {key:?} must be a non-negative integer, got {n}"));
    }
    Ok(n as u64)
}

/// Validate a whole telemetry stream: every non-empty line must parse
/// as a schema v1/v2 row, a summary, or an event line. Returns the
/// number of *data* rows on success (summary and event lines validate
/// but do not count), or the first offending line (1-based) and its
/// error. A truncated final line is tolerated — see
/// [`validate_jsonl_detailed`].
pub fn validate_jsonl(text: &str) -> Result<usize, String> {
    validate_jsonl_detailed(text).map(|(rows, _, _)| rows)
}

/// Full stream validation: `(data_rows, event_lines, truncated_tail)`.
///
/// A final line that fails to parse **and** is not newline-terminated
/// is a truncated tail — the partial row a crashed run leaves behind —
/// and is reported through the third field instead of failing the
/// stream. A bad line anywhere else (or a newline-terminated bad final
/// line) is still an error.
pub fn validate_jsonl_detailed(text: &str) -> Result<(usize, usize, bool), String> {
    let mut rows = 0;
    let mut events = 0;
    let lines: Vec<(usize, &str)> = text.lines().enumerate().collect();
    let last_idx = lines
        .iter()
        .rev()
        .find(|(_, l)| !l.trim().is_empty())
        .map(|(i, _)| *i);
    for (i, line) in lines {
        if line.trim().is_empty() {
            continue;
        }
        match TelemetryLine::parse(line) {
            Ok(TelemetryLine::Row(_)) => rows += 1,
            Ok(TelemetryLine::Summary(_)) => {}
            Ok(TelemetryLine::Event(_)) => events += 1,
            Err(e) => {
                if Some(i) == last_idx && !text.ends_with('\n') {
                    return Ok((rows, events, true));
                }
                return Err(format!("line {}: {e}", i + 1));
            }
        }
    }
    Ok((rows, events, false))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> TelemetryRow {
        TelemetryRow {
            round: 12,
            node: 3,
            residual: 0.125,
            doubles_sent: 40.0,
            doubles_recv: 80.5,
            bytes_on_wire: 356,
            wall_micros: 1812,
            queue_depth: 2,
            staleness: 1,
            stalls: 4,
            retransmits: 1,
            dedups: 2,
            drops_injected: 1,
            dups_injected: 2,
            wait_micros: 311,
            drain_micros: 44,
            compute_micros: 1200,
            encode_micros: 180,
            send_micros: 77,
        }
    }

    /// A hand-written v1 line (no phase spans), as PR 8 wrote them.
    fn v1_line() -> String {
        "{\"v\":1,\"round\":12,\"node\":3,\"residual\":0.125,\"doubles_sent\":40,\
         \"doubles_recv\":80.5,\"bytes_on_wire\":356,\"wall_micros\":1812,\
         \"queue_depth\":2,\"staleness\":1,\"stalls\":4,\"retransmits\":1,\
         \"dedups\":2,\"drops_injected\":1,\"dups_injected\":2}"
            .to_string()
    }

    #[test]
    fn row_roundtrips_through_jsonl() {
        let row = sample();
        let line = row.to_json_line();
        assert!(!line.contains('\n'), "a row must be a single line");
        assert_eq!(TelemetryRow::from_json_line(&line).unwrap(), row);
    }

    #[test]
    fn v1_rows_still_parse_with_zero_phase_spans() {
        let row = TelemetryRow::from_json_line(&v1_line()).unwrap();
        assert_eq!(row.round, 12);
        assert_eq!(row.node, 3);
        assert_eq!(row.residual, 0.125);
        assert_eq!(
            (row.wait_micros, row.drain_micros, row.compute_micros,
             row.encode_micros, row.send_micros),
            (0, 0, 0, 0, 0),
            "v1 rows predate phase spans"
        );
    }

    #[test]
    fn future_versions_fail_with_a_named_error_not_a_panic() {
        let line = sample().to_json_line().replace("\"v\":2", "\"v\":3");
        let err = TelemetryRow::from_json_line(&line).unwrap_err();
        assert!(err.contains("unsupported telemetry schema v3"), "{err}");
        let err = TelemetryLine::parse(&line).unwrap_err();
        assert!(err.contains("unsupported telemetry schema v3"), "{err}");
    }

    #[test]
    fn v2_rows_require_phase_spans() {
        // a v2 row missing its phase keys is malformed, not defaulted
        let line = v1_line().replace("\"v\":1", "\"v\":2");
        let err = TelemetryRow::from_json_line(&line).unwrap_err();
        assert!(err.contains("wait_micros"), "{err}");
    }

    #[test]
    fn parse_rejects_bad_rows() {
        assert!(TelemetryRow::from_json_line("not json").is_err());
        assert!(TelemetryRow::from_json_line("{}").is_err(), "missing keys");
        // wrong version
        let line = sample().to_json_line().replace("\"v\":2", "\"v\":99");
        assert!(TelemetryRow::from_json_line(&line).is_err());
        // non-integer integer field
        let line = sample().to_json_line().replace("\"round\":12", "\"round\":1.5");
        assert!(TelemetryRow::from_json_line(&line).is_err());
        // negative counter
        let line = sample().to_json_line().replace("\"dedups\":2", "\"dedups\":-2");
        assert!(TelemetryRow::from_json_line(&line).is_err());
    }

    #[test]
    fn summary_line_roundtrips_and_is_distinguished() {
        let s = TelemetrySummary { rows_written: 240, rows_dropped: 3 };
        let line = s.to_json_line();
        assert!(!line.contains('\n'));
        match TelemetryLine::parse(&line).unwrap() {
            TelemetryLine::Summary(back) => assert_eq!(back, s),
            other => panic!("expected summary, got {other:?}"),
        }
        match TelemetryLine::parse(&sample().to_json_line()).unwrap() {
            TelemetryLine::Row(back) => assert_eq!(back, sample()),
            other => panic!("expected row, got {other:?}"),
        }
        assert!(TelemetryLine::parse("{\"v\":2,\"kind\":\"mystery\"}").is_err());
    }

    #[test]
    fn event_lines_dispatch_and_roundtrip_through_the_stream_parser() {
        use super::super::events::{EventKind, RunEvent};
        let ev = RunEvent::new(EventKind::Retransmit)
            .node(1)
            .peer(2)
            .round(5)
            .seq(9)
            .detail("2 frame(s) [9, 11)");
        let line = ev.to_json_line();
        match TelemetryLine::parse(&line).unwrap() {
            TelemetryLine::Event(back) => assert_eq!(back, ev),
            other => panic!("expected event, got {other:?}"),
        }
        assert_eq!(TelemetryLine::parse(&line).unwrap().to_json_line(), line);
    }

    #[test]
    fn parse_lenient_skips_unknown_kinds_but_not_malformed_lines() {
        assert_eq!(
            TelemetryLine::parse_lenient("{\"v\":99,\"kind\":\"hologram\"}"),
            Ok(None),
            "future kinds are skippable, whatever their version"
        );
        assert!(TelemetryLine::parse_lenient("not json").is_err());
        assert!(
            TelemetryLine::parse_lenient("{\"v\":2,\"round\":0}").is_err(),
            "a kind-less line is a row and rows stay strict"
        );
    }

    #[test]
    fn validate_tolerates_a_truncated_final_line_only() {
        let row = sample().to_json_line();
        // a partial last line without its newline: a crashed run's tail
        let truncated = format!("{row}\n{{\"v\":2,\"round\":");
        assert_eq!(validate_jsonl(&truncated), Ok(1));
        assert_eq!(validate_jsonl_detailed(&truncated), Ok((1, 0, true)));
        // the same junk, newline-terminated, is a corrupt stream
        let terminated = format!("{row}\n{{\"v\":2,\"round\":\n");
        assert!(validate_jsonl(&terminated).is_err());
        // junk in the middle always fails, trailing newline or not
        let middle = format!("garbage\n{row}");
        assert!(validate_jsonl(&middle).is_err());
    }

    #[test]
    fn validate_counts_events_separately_from_rows() {
        use super::super::events::{EventKind, RunEvent};
        let stream = format!(
            "{}\n{}\n{}\n",
            RunEvent::new(EventKind::Handshake).node(0).peer(1).to_json_line(),
            sample().to_json_line(),
            RunEvent::new(EventKind::Dedup).node(0).peer(1).seq(3).to_json_line(),
        );
        assert_eq!(validate_jsonl(&stream), Ok(1), "events are not data rows");
        assert_eq!(validate_jsonl_detailed(&stream), Ok((1, 2, false)));
    }

    #[test]
    fn validate_jsonl_counts_rows_and_names_bad_lines() {
        let good = format!("{}\n\n{}\n", sample().to_json_line(), sample().to_json_line());
        assert_eq!(validate_jsonl(&good), Ok(2));
        assert_eq!(validate_jsonl(""), Ok(0));
        let bad = format!("{}\ngarbage\n", sample().to_json_line());
        let err = validate_jsonl(&bad).unwrap_err();
        assert!(err.starts_with("line 2:"), "{err}");
        // the trailing writer summary validates but is not a data row
        let with_summary = format!(
            "{}\n{}\n",
            sample().to_json_line(),
            TelemetrySummary { rows_written: 1, rows_dropped: 0 }.to_json_line()
        );
        assert_eq!(validate_jsonl(&with_summary), Ok(1));
        // a mixed v1 + v2 stream (schema upgrade mid-rotation) validates
        let mixed = format!("{}\n{}\n", v1_line(), sample().to_json_line());
        assert_eq!(validate_jsonl(&mixed), Ok(2));
    }
}
