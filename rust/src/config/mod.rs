//! Experiment configuration: JSON-serializable description of a full run
//! (dataset profile, topology, problem, method, hyper-parameters).
//!
//! Problems are resolved by name through
//! [`crate::operators::ProblemRegistry`] — the config layer holds no
//! problem list of its own, so registering a new workload automatically
//! makes it reachable from JSON configs and every CLI flag.

use crate::algorithms::AlgorithmKind;
use crate::comm::CommCostModel;
use crate::coordinator::Experiment;
use crate::data::{load_libsvm, Dataset, SyntheticSpec};
use crate::graph::{Topology, TopologyKind};
use crate::operators::{ProblemEntry, ProblemRegistry, ProblemSpec};
use crate::runtime::{EngineKind, EngineSpec, TransportKind};
use crate::util::json::{parse, Json};

/// Full experiment description.
#[derive(Clone, Debug, PartialEq)]
pub struct ExperimentConfig {
    /// problem name or alias, resolved through the registry
    pub problem: String,
    /// problem-specific knobs forwarded verbatim to the registry
    /// constructor (e.g. `{"l1": 0.01}`); `Json::Null` = all defaults
    pub problem_params: Json,
    /// synthetic profile name (news20/rcv1/sector/tiny) or libsvm: path
    pub dataset: String,
    /// override sample count (0 = profile default)
    pub samples: usize,
    /// override dimension (0 = profile default)
    pub dim: usize,
    /// l2 weight; <0 means the paper's 1/(10 Q) default
    pub lambda: f64,
    pub nodes: usize,
    pub topology: TopologyKind,
    /// ER edge probability (paper: 0.4)
    pub edge_prob: f64,
    pub algorithm: AlgorithmKind,
    pub alpha: f64,
    pub passes: f64,
    pub seed: u64,
    pub record_points: usize,
    /// count sparse index/value pairs as 2 doubles (default) or 1
    pub charitable_sparse: bool,
    /// execution engine: round driver, threads, transport, endpoints
    pub engine: EngineSpec,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            problem: "ridge".into(),
            problem_params: Json::Null,
            dataset: "rcv1-like".into(),
            samples: 0,
            dim: 0,
            lambda: -1.0,
            nodes: 10,
            topology: TopologyKind::ErdosRenyi,
            edge_prob: 0.4,
            algorithm: AlgorithmKind::Dsba,
            alpha: 0.5,
            passes: 20.0,
            seed: 42,
            record_points: 40,
            charitable_sparse: false,
            engine: EngineSpec::default(),
        }
    }
}

impl ExperimentConfig {
    /// Registry entry for the configured problem (resolves aliases).
    pub fn problem_entry(&self) -> Result<&'static ProblemEntry, String> {
        ProblemRegistry::builtin().resolve(&self.problem).ok_or_else(|| {
            format!(
                "unknown problem {:?} (available: {})",
                self.problem,
                ProblemRegistry::builtin().names().join(", ")
            )
        })
    }

    /// Parse from a JSON document (missing keys keep defaults).
    ///
    /// The engine accepts both the nested object form written by
    /// [`ExperimentConfig::to_json`] and the legacy flat keys
    /// (`"engine"` as a bare string plus top-level `threads` /
    /// `transport` / `listen` / `peers` / `hosted` / `compress` /
    /// `mode` / `fault` / `telemetry`).
    pub fn from_json(src: &str) -> Result<ExperimentConfig, String> {
        let v = parse(src)?;
        let mut c = ExperimentConfig::default();
        if let Some(s) = v.get("problem").and_then(Json::as_str) {
            // resolve eagerly so bad names fail at parse time, and store
            // the canonical spelling so serialization round-trips
            c.problem = ProblemRegistry::builtin()
                .canonical(s)
                .ok_or(format!("bad problem {s}"))?
                .to_string();
        }
        if let Some(p) = v.get("params") {
            c.problem_params = p.clone();
        }
        if let Some(s) = v.get("dataset").and_then(Json::as_str) {
            c.dataset = s.to_string();
        }
        if let Some(n) = v.get("samples").and_then(Json::as_usize) {
            c.samples = n;
        }
        if let Some(n) = v.get("dim").and_then(Json::as_usize) {
            c.dim = n;
        }
        if let Some(x) = v.get("lambda").and_then(Json::as_f64) {
            c.lambda = x;
        }
        if let Some(n) = v.get("nodes").and_then(Json::as_usize) {
            c.nodes = n;
        }
        if let Some(s) = v.get("topology").and_then(Json::as_str) {
            c.topology = TopologyKind::parse(s).ok_or(format!("bad topology {s}"))?;
        }
        if let Some(x) = v.get("edge_prob").and_then(Json::as_f64) {
            c.edge_prob = x;
        }
        if let Some(s) = v.get("algorithm").and_then(Json::as_str) {
            c.algorithm =
                AlgorithmKind::parse(s).ok_or(format!("bad algorithm {s}"))?;
        }
        if let Some(x) = v.get("alpha").and_then(Json::as_f64) {
            c.alpha = x;
        }
        if let Some(x) = v.get("passes").and_then(Json::as_f64) {
            c.passes = x;
        }
        if let Some(n) = v.get("seed").and_then(Json::as_usize) {
            c.seed = n as u64;
        }
        if let Some(n) = v.get("record_points").and_then(Json::as_usize) {
            c.record_points = n;
        }
        if let Some(b) = v.get("charitable_sparse").and_then(|j| j.as_bool()) {
            c.charitable_sparse = b;
        }
        if let Some(e) = v.get("engine") {
            c.engine = EngineSpec::from_json(e)?;
        }
        // legacy flat engine keys (pre-EngineSpec config files)
        if let Some(n) = v.get("threads").and_then(Json::as_usize) {
            c.engine.threads = n;
        }
        if let Some(s) = v.get("transport").and_then(Json::as_str) {
            c.engine.transport =
                TransportKind::parse(s).ok_or(format!("bad transport {s}"))?;
        }
        if let Some(s) = v.get("listen").and_then(Json::as_str) {
            c.engine.tcp.listen = s.to_string();
        }
        if let Some(s) = v.get("peers").and_then(Json::as_str) {
            c.engine.tcp.peers = s.to_string();
        }
        if let Some(s) = v.get("hosted").and_then(Json::as_str) {
            c.engine.tcp.hosted = s.to_string();
        }
        if let Some(s) = v.get("compress").and_then(Json::as_str) {
            c.engine.compress = crate::comm::CompressionSpec::parse(s)?;
        }
        if let Some(s) = v.get("mode").and_then(Json::as_str) {
            c.engine.mode = crate::runtime::ModeSpec::parse(s)
                .ok_or(format!("bad mode {s} (sync|async:TAU)"))?;
        }
        if let Some(s) = v.get("fault").and_then(Json::as_str) {
            c.engine.fault = crate::runtime::FaultSpec::parse(s)?;
        }
        if let Some(t) = v.get("telemetry") {
            c.engine.telemetry = crate::telemetry::TelemetrySpec::from_json(t)?;
        }
        Ok(c)
    }

    /// Serialize every field; `from_json(to_json(c)) == c` is pinned by
    /// a property test so a field added on one side cannot be silently
    /// dropped by the other.
    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("problem", Json::Str(self.problem.clone())),
            ("params", self.problem_params.clone()),
            ("dataset", Json::Str(self.dataset.clone())),
            ("samples", Json::Num(self.samples as f64)),
            ("dim", Json::Num(self.dim as f64)),
            ("lambda", Json::Num(self.lambda)),
            ("nodes", Json::Num(self.nodes as f64)),
            ("topology", Json::Str(self.topology.name().into())),
            ("edge_prob", Json::Num(self.edge_prob)),
            ("algorithm", Json::Str(self.algorithm.name().into())),
            ("alpha", Json::Num(self.alpha)),
            ("passes", Json::Num(self.passes)),
            ("seed", Json::Num(self.seed as f64)),
            ("record_points", Json::Num(self.record_points as f64)),
            ("charitable_sparse", Json::Bool(self.charitable_sparse)),
            ("engine", self.engine.to_json()),
        ])
    }

    /// Materialize the dataset (synthetic profile or `libsvm:<path>`).
    pub fn build_dataset(&self) -> Result<Dataset, String> {
        let entry = self.problem_entry()?;
        let mut ds = if let Some(path) = self.dataset.strip_prefix("libsvm:") {
            let mut d = load_libsvm(path, self.dim)?;
            d.normalize_rows();
            d
        } else {
            let mut spec = SyntheticSpec::by_name(&self.dataset)
                .ok_or_else(|| format!("unknown dataset {}", self.dataset))?;
            if self.samples > 0 {
                spec = spec.with_samples(self.samples);
            }
            if self.dim > 0 {
                spec = spec.with_dim(self.dim);
            }
            if entry.meta.regression_targets {
                spec = spec.with_regression(true);
            }
            spec.generate(self.seed ^ 0xda7a)
        };
        if ds.samples() < self.nodes {
            return Err("dataset smaller than node count".into());
        }
        ds.normalize_rows();
        Ok(ds)
    }

    /// Effective lambda (paper default `1/(10 Q)` when unset).
    pub fn effective_lambda(&self, total_samples: usize) -> f64 {
        if self.lambda >= 0.0 {
            self.lambda
        } else {
            1.0 / (10.0 * total_samples as f64)
        }
    }

    /// Build problem + topology + experiment, resolving the problem
    /// through the registry.
    pub fn build(&self) -> Result<Experiment, String> {
        let entry = self.problem_entry()?;
        let ds = self.build_dataset()?;
        let part = ds.partition_seeded(self.nodes, self.seed ^ 0x9a47);
        let lam = self.effective_lambda(part.total_samples());
        let topo =
            Topology::generate(self.topology, self.nodes, self.edge_prob, self.seed ^ 0x109);
        // reject malformed or under-specified TCP specs here, on the
        // clean error path, so only genuine socket failures can surface
        // later inside `run()` — the sequential oracle ignores the
        // transport entirely, so don't gate it on these specs
        if self.engine.kind == EngineKind::Parallel
            && self.engine.transport == TransportKind::Tcp
        {
            crate::runtime::transport::validate_tcp_spec(
                &topo,
                &self.engine.tcp.hosted,
                &self.engine.tcp.peers,
            )?;
        }
        let spec = ProblemSpec::new(entry.meta.name, lam)
            .with_params(self.problem_params.clone());
        let problem = entry.build(&spec, &ds, part)?;
        let cost = if self.charitable_sparse {
            CommCostModel::values_only()
        } else {
            CommCostModel::default()
        };
        Ok(Experiment::builder_from_arc(problem, topo, self.algorithm)
            .step_size(self.alpha)
            .passes(self.passes)
            .seed(self.seed)
            .record_points(self.record_points)
            .cost_model(cost)
            .engine(self.engine.clone())
            .build())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::TcpSpec;

    #[test]
    fn json_roundtrip() {
        let c = ExperimentConfig {
            problem: "auc".into(),
            dataset: "tiny".into(),
            alpha: 0.25,
            nodes: 4,
            ..Default::default()
        };
        let j = c.to_json().to_string();
        let c2 = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(c2, c);
    }

    #[test]
    fn problem_aliases_canonicalize_at_parse_time() {
        let c = ExperimentConfig::from_json("{\"problem\":\"LogReg\"}").unwrap();
        assert_eq!(c.problem, "logistic");
    }

    #[test]
    fn default_lambda_is_paper_value() {
        let c = ExperimentConfig::default();
        assert!((c.effective_lambda(1000) - 1.0 / 10_000.0).abs() < 1e-15);
        let c2 = ExperimentConfig { lambda: 0.5, ..Default::default() };
        assert_eq!(c2.effective_lambda(1000), 0.5);
    }

    #[test]
    fn builds_tiny_experiment() {
        let c = ExperimentConfig {
            dataset: "tiny".into(),
            nodes: 4,
            passes: 2.0,
            ..Default::default()
        };
        let mut exp = c.build().unwrap();
        let trace = exp.run();
        assert!(!trace.rows.is_empty());
    }

    #[test]
    fn rejects_bad_fields() {
        assert!(ExperimentConfig::from_json("{\"problem\":\"nope\"}").is_err());
        assert!(ExperimentConfig::from_json("{\"algorithm\":\"nope\"}").is_err());
        assert!(ExperimentConfig::from_json("{\"engine\":\"warp\"}").is_err());
        assert!(ExperimentConfig::from_json("{\"transport\":\"pigeon\"}").is_err());
        assert!(
            ExperimentConfig::from_json("{\"engine\":{\"transport\":\"pigeon\"}}").is_err()
        );
        assert!(ExperimentConfig::from_json("not json").is_err());
        // malformed TCP specs fail at build(), not as a panic inside run()
        let base = ExperimentConfig {
            dataset: "tiny".into(),
            nodes: 4,
            engine: EngineSpec::parallel(0).with_transport(TransportKind::Tcp),
            ..Default::default()
        };
        let bad_hosted = ExperimentConfig {
            engine: base.engine.clone().with_tcp(TcpSpec {
                hosted: "0-4000000000".into(),
                ..TcpSpec::default()
            }),
            ..base.clone()
        };
        assert!(bad_hosted.build().is_err());
        let bad_peers = ExperimentConfig {
            engine: base.engine.clone().with_tcp(TcpSpec {
                peers: "5=".into(),
                ..TcpSpec::default()
            }),
            ..base.clone()
        };
        assert!(bad_peers.build().is_err());
        // hosting a subset without addresses for the remote neighbors
        // must also fail at build(), not panic during run()
        let missing_peers = ExperimentConfig {
            engine: base.engine.clone().with_tcp(TcpSpec {
                hosted: "0-1".into(),
                ..TcpSpec::default()
            }),
            ..base
        };
        assert!(missing_peers.build().is_err());
    }

    #[test]
    fn engine_fields_roundtrip() {
        let c = ExperimentConfig {
            engine: EngineSpec {
                kind: EngineKind::Parallel,
                threads: 3,
                transport: TransportKind::Tcp,
                tcp: TcpSpec {
                    listen: "127.0.0.1:9100".into(),
                    peers: "5=10.0.0.2:9100".into(),
                    hosted: "0-4".into(),
                },
                compress: crate::comm::CompressionSpec::RandK(5),
                mode: crate::runtime::ModeSpec::Async(3),
                fault: crate::runtime::FaultSpec::parse("drop:0.05,dup:0.05,kill:2@9")
                    .unwrap(),
                telemetry: crate::telemetry::TelemetrySpec {
                    path: "results/run.jsonl".into(),
                    max_bytes: 1 << 20,
                    keep: 2,
                },
            },
            ..Default::default()
        };
        let c2 = ExperimentConfig::from_json(&c.to_json().to_string()).unwrap();
        assert_eq!(c2.engine, c.engine);
    }

    #[test]
    fn legacy_flat_engine_keys_accepted() {
        let c = ExperimentConfig::from_json(
            "{\"engine\":\"parallel\",\"threads\":3,\"transport\":\"tcp\",\
             \"listen\":\"127.0.0.1:9100\",\"peers\":\"5=h:1\",\"hosted\":\"0-4\",\
             \"compress\":\"qsgd:32\",\"mode\":\"async:2\",\
             \"fault\":\"drop:0.1\",\"telemetry\":\"run.jsonl\"}",
        )
        .unwrap();
        assert_eq!(c.engine.kind, EngineKind::Parallel);
        assert_eq!(c.engine.threads, 3);
        assert_eq!(c.engine.transport, TransportKind::Tcp);
        assert_eq!(c.engine.tcp.listen, "127.0.0.1:9100");
        assert_eq!(c.engine.tcp.peers, "5=h:1");
        assert_eq!(c.engine.tcp.hosted, "0-4");
        assert_eq!(c.engine.compress, crate::comm::CompressionSpec::Qsgd(32));
        assert_eq!(c.engine.mode, crate::runtime::ModeSpec::Async(2));
        assert_eq!(c.engine.fault, crate::runtime::FaultSpec::parse("drop:0.1").unwrap());
        assert_eq!(c.engine.telemetry.path, "run.jsonl");
        assert!(ExperimentConfig::from_json("{\"compress\":\"zip\"}").is_err());
        assert!(ExperimentConfig::from_json("{\"mode\":\"warp\"}").is_err());
        assert!(ExperimentConfig::from_json("{\"fault\":\"warp:1\"}").is_err());
    }
}
