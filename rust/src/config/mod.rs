//! Experiment configuration: JSON-serializable description of a full run
//! (dataset profile, topology, problem, method, hyper-parameters) plus
//! presets for every figure of the paper.

use crate::algorithms::AlgorithmKind;
use crate::comm::CommCostModel;
use crate::coordinator::Experiment;
use crate::data::{load_libsvm, Dataset, SyntheticSpec};
use crate::graph::{Topology, TopologyKind};
use crate::operators::{AucProblem, LogisticProblem, Problem, RidgeProblem};
use crate::runtime::{EngineKind, TransportKind};
use crate::util::json::{parse, Json};
use std::sync::Arc;

/// Which learning problem of §7 to instantiate.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ProblemKind {
    Ridge,
    Logistic,
    Auc,
}

impl ProblemKind {
    pub fn parse(s: &str) -> Option<ProblemKind> {
        Some(match s.to_ascii_lowercase().as_str() {
            "ridge" => ProblemKind::Ridge,
            "logistic" => ProblemKind::Logistic,
            "auc" => ProblemKind::Auc,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            ProblemKind::Ridge => "ridge",
            ProblemKind::Logistic => "logistic",
            ProblemKind::Auc => "auc",
        }
    }
}

/// Full experiment description.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    pub problem: ProblemKind,
    /// synthetic profile name (news20/rcv1/sector/tiny) or libsvm: path
    pub dataset: String,
    /// override sample count (0 = profile default)
    pub samples: usize,
    /// override dimension (0 = profile default)
    pub dim: usize,
    /// l2 weight; <0 means the paper's 1/(10 Q) default
    pub lambda: f64,
    pub nodes: usize,
    pub topology: TopologyKind,
    /// ER edge probability (paper: 0.4)
    pub edge_prob: f64,
    pub algorithm: AlgorithmKind,
    pub alpha: f64,
    pub passes: f64,
    pub seed: u64,
    pub record_points: usize,
    /// count sparse index/value pairs as 2 doubles (default) or 1
    pub charitable_sparse: bool,
    /// round driver: sequential reference oracle or parallel engine
    pub engine: EngineKind,
    /// parallel-engine worker threads (0 = auto: cores capped by nodes)
    pub threads: usize,
    /// parallel-engine edge channels: in-process mpsc or per-edge TCP
    pub transport: TransportKind,
    /// TCP listen address ("" = ephemeral loopback port)
    pub listen: String,
    /// TCP peers spec: comma-separated `node=host:port` for remote nodes
    pub peers: String,
    /// TCP hosted-node spec ("" = host all nodes in this process)
    pub hosted: String,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            problem: ProblemKind::Ridge,
            dataset: "rcv1-like".into(),
            samples: 0,
            dim: 0,
            lambda: -1.0,
            nodes: 10,
            topology: TopologyKind::ErdosRenyi,
            edge_prob: 0.4,
            algorithm: AlgorithmKind::Dsba,
            alpha: 0.5,
            passes: 20.0,
            seed: 42,
            record_points: 40,
            charitable_sparse: false,
            engine: EngineKind::Sequential,
            threads: 0,
            transport: TransportKind::Local,
            listen: String::new(),
            peers: String::new(),
            hosted: String::new(),
        }
    }
}

impl ExperimentConfig {
    /// Parse from a JSON document (missing keys keep defaults).
    pub fn from_json(src: &str) -> Result<ExperimentConfig, String> {
        let v = parse(src)?;
        let mut c = ExperimentConfig::default();
        if let Some(s) = v.get("problem").and_then(Json::as_str) {
            c.problem = ProblemKind::parse(s).ok_or(format!("bad problem {s}"))?;
        }
        if let Some(s) = v.get("dataset").and_then(Json::as_str) {
            c.dataset = s.to_string();
        }
        if let Some(n) = v.get("samples").and_then(Json::as_usize) {
            c.samples = n;
        }
        if let Some(n) = v.get("dim").and_then(Json::as_usize) {
            c.dim = n;
        }
        if let Some(x) = v.get("lambda").and_then(Json::as_f64) {
            c.lambda = x;
        }
        if let Some(n) = v.get("nodes").and_then(Json::as_usize) {
            c.nodes = n;
        }
        if let Some(s) = v.get("topology").and_then(Json::as_str) {
            c.topology = TopologyKind::parse(s).ok_or(format!("bad topology {s}"))?;
        }
        if let Some(x) = v.get("edge_prob").and_then(Json::as_f64) {
            c.edge_prob = x;
        }
        if let Some(s) = v.get("algorithm").and_then(Json::as_str) {
            c.algorithm =
                AlgorithmKind::parse(s).ok_or(format!("bad algorithm {s}"))?;
        }
        if let Some(x) = v.get("alpha").and_then(Json::as_f64) {
            c.alpha = x;
        }
        if let Some(x) = v.get("passes").and_then(Json::as_f64) {
            c.passes = x;
        }
        if let Some(n) = v.get("seed").and_then(Json::as_usize) {
            c.seed = n as u64;
        }
        if let Some(n) = v.get("record_points").and_then(Json::as_usize) {
            c.record_points = n;
        }
        if let Some(b) = v.get("charitable_sparse").and_then(|j| j.as_bool()) {
            c.charitable_sparse = b;
        }
        if let Some(s) = v.get("engine").and_then(Json::as_str) {
            c.engine = EngineKind::parse(s).ok_or(format!("bad engine {s}"))?;
        }
        if let Some(n) = v.get("threads").and_then(Json::as_usize) {
            c.threads = n;
        }
        if let Some(s) = v.get("transport").and_then(Json::as_str) {
            c.transport = TransportKind::parse(s).ok_or(format!("bad transport {s}"))?;
        }
        if let Some(s) = v.get("listen").and_then(Json::as_str) {
            c.listen = s.to_string();
        }
        if let Some(s) = v.get("peers").and_then(Json::as_str) {
            c.peers = s.to_string();
        }
        if let Some(s) = v.get("hosted").and_then(Json::as_str) {
            c.hosted = s.to_string();
        }
        Ok(c)
    }

    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("problem", Json::Str(self.problem.name().into())),
            ("dataset", Json::Str(self.dataset.clone())),
            ("samples", Json::Num(self.samples as f64)),
            ("dim", Json::Num(self.dim as f64)),
            ("lambda", Json::Num(self.lambda)),
            ("nodes", Json::Num(self.nodes as f64)),
            ("topology", Json::Str(self.topology.name().into())),
            ("edge_prob", Json::Num(self.edge_prob)),
            ("algorithm", Json::Str(self.algorithm.name().into())),
            ("alpha", Json::Num(self.alpha)),
            ("passes", Json::Num(self.passes)),
            ("seed", Json::Num(self.seed as f64)),
            ("record_points", Json::Num(self.record_points as f64)),
            ("charitable_sparse", Json::Bool(self.charitable_sparse)),
            ("engine", Json::Str(self.engine.name().into())),
            ("threads", Json::Num(self.threads as f64)),
            ("transport", Json::Str(self.transport.name().into())),
            ("listen", Json::Str(self.listen.clone())),
            ("peers", Json::Str(self.peers.clone())),
            ("hosted", Json::Str(self.hosted.clone())),
        ])
    }

    /// Materialize the dataset (synthetic profile or `libsvm:<path>`).
    pub fn build_dataset(&self) -> Result<Dataset, String> {
        let mut ds = if let Some(path) = self.dataset.strip_prefix("libsvm:") {
            let mut d = load_libsvm(path, self.dim)?;
            d.normalize_rows();
            d
        } else {
            let mut spec = SyntheticSpec::by_name(&self.dataset)
                .ok_or_else(|| format!("unknown dataset {}", self.dataset))?;
            if self.samples > 0 {
                spec = spec.with_samples(self.samples);
            }
            if self.dim > 0 {
                spec = spec.with_dim(self.dim);
            }
            if self.problem == ProblemKind::Ridge {
                spec = spec.with_regression(true);
            }
            spec.generate(self.seed ^ 0xda7a)
        };
        if ds.samples() < self.nodes {
            return Err("dataset smaller than node count".into());
        }
        ds.normalize_rows();
        Ok(ds)
    }

    /// Effective lambda (paper default `1/(10 Q)` when unset).
    pub fn effective_lambda(&self, total_samples: usize) -> f64 {
        if self.lambda >= 0.0 {
            self.lambda
        } else {
            1.0 / (10.0 * total_samples as f64)
        }
    }

    /// Build problem + topology + experiment.
    pub fn build(&self) -> Result<Experiment, String> {
        let ds = self.build_dataset()?;
        let part = ds.partition_seeded(self.nodes, self.seed ^ 0x9a47);
        let lam = self.effective_lambda(part.total_samples());
        let topo =
            Topology::generate(self.topology, self.nodes, self.edge_prob, self.seed ^ 0x109);
        // reject malformed or under-specified TCP specs here, on the
        // clean error path, so only genuine socket failures can surface
        // later inside `run()` — the sequential oracle ignores the
        // transport entirely, so don't gate it on these specs
        if self.engine == EngineKind::Parallel && self.transport == TransportKind::Tcp {
            crate::runtime::transport::validate_tcp_spec(&topo, &self.hosted, &self.peers)?;
        }
        let problem: Arc<dyn Problem> = match self.problem {
            ProblemKind::Ridge => Arc::new(RidgeProblem::new(part, lam)),
            ProblemKind::Logistic => Arc::new(LogisticProblem::new(part, lam)),
            ProblemKind::Auc => Arc::new(AucProblem::new(part, lam)),
        };
        let cost = if self.charitable_sparse {
            CommCostModel::values_only()
        } else {
            CommCostModel::default()
        };
        Ok(Experiment::from_arc(problem, topo, self.algorithm)
            .with_step_size(self.alpha)
            .with_passes(self.passes)
            .with_seed(self.seed)
            .with_record_points(self.record_points)
            .with_cost_model(cost)
            .with_engine(self.engine, self.threads)
            .with_transport(self.transport)
            .with_tcp_endpoints(&self.listen, &self.peers, &self.hosted))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_roundtrip() {
        let c = ExperimentConfig {
            problem: ProblemKind::Auc,
            dataset: "tiny".into(),
            alpha: 0.25,
            nodes: 4,
            ..Default::default()
        };
        let j = c.to_json().to_string();
        let c2 = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(c2.problem, ProblemKind::Auc);
        assert_eq!(c2.alpha, 0.25);
        assert_eq!(c2.nodes, 4);
    }

    #[test]
    fn default_lambda_is_paper_value() {
        let c = ExperimentConfig::default();
        assert!((c.effective_lambda(1000) - 1.0 / 10_000.0).abs() < 1e-15);
        let c2 = ExperimentConfig { lambda: 0.5, ..Default::default() };
        assert_eq!(c2.effective_lambda(1000), 0.5);
    }

    #[test]
    fn builds_tiny_experiment() {
        let c = ExperimentConfig {
            dataset: "tiny".into(),
            nodes: 4,
            passes: 2.0,
            ..Default::default()
        };
        let mut exp = c.build().unwrap();
        let trace = exp.run();
        assert!(!trace.rows.is_empty());
    }

    #[test]
    fn rejects_bad_fields() {
        assert!(ExperimentConfig::from_json("{\"problem\":\"nope\"}").is_err());
        assert!(ExperimentConfig::from_json("{\"algorithm\":\"nope\"}").is_err());
        assert!(ExperimentConfig::from_json("{\"engine\":\"warp\"}").is_err());
        assert!(ExperimentConfig::from_json("{\"transport\":\"pigeon\"}").is_err());
        assert!(ExperimentConfig::from_json("not json").is_err());
        // malformed TCP specs fail at build(), not as a panic inside run()
        let base = ExperimentConfig {
            dataset: "tiny".into(),
            nodes: 4,
            engine: EngineKind::Parallel,
            transport: TransportKind::Tcp,
            ..Default::default()
        };
        let bad_hosted =
            ExperimentConfig { hosted: "0-4000000000".into(), ..base.clone() };
        assert!(bad_hosted.build().is_err());
        let bad_peers = ExperimentConfig { peers: "5=".into(), ..base.clone() };
        assert!(bad_peers.build().is_err());
        // hosting a subset without addresses for the remote neighbors
        // must also fail at build(), not panic during run()
        let missing_peers = ExperimentConfig { hosted: "0-1".into(), ..base };
        assert!(missing_peers.build().is_err());
    }

    #[test]
    fn engine_fields_roundtrip() {
        let c = ExperimentConfig {
            engine: EngineKind::Parallel,
            threads: 3,
            transport: TransportKind::Tcp,
            listen: "127.0.0.1:9100".into(),
            peers: "5=10.0.0.2:9100".into(),
            hosted: "0-4".into(),
            ..Default::default()
        };
        let c2 = ExperimentConfig::from_json(&c.to_json().to_string()).unwrap();
        assert_eq!(c2.engine, EngineKind::Parallel);
        assert_eq!(c2.threads, 3);
        assert_eq!(c2.transport, TransportKind::Tcp);
        assert_eq!(c2.listen, "127.0.0.1:9100");
        assert_eq!(c2.peers, "5=10.0.0.2:9100");
        assert_eq!(c2.hosted, "0-4");
    }
}
