//! Minimal JSON: enough to read `artifacts/manifest.json`, experiment
//! configs, and to write metrics/figure series. No external deps.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// `obj["key"]` access that tolerates misses.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    pub fn from_pairs(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    // compact serialization lives in the `Display` impl below
    // (`.to_string()` keeps working through the blanket `ToString`)

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.is_finite() {
                    if *n == n.trunc() && n.abs() < 1e15 {
                        let _ = write!(out, "{}", *n as i64);
                    } else {
                        let _ = write!(out, "{n}");
                    }
                } else {
                    out.push_str("null"); // JSON has no Inf/NaN
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\r' => out.push_str("\\r"),
                        '\t' => out.push_str("\\t"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Compact serialization (also reachable as `.to_string()`).
impl std::fmt::Display for Json {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut s = String::new();
        self.write(&mut s);
        f.write_str(&s)
    }
}

/// Parse a JSON document.
pub fn parse(src: &str) -> Result<Json, String> {
    let bytes = src.as_bytes();
    let mut p = Parser { b: bytes, i: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != bytes.len() {
        return Err(format!("trailing data at byte {}", p.i));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected {:?} at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other, self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                    .map_err(|_| "bad \\u escape".to_string())?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| "bad \\u escape".to_string())?;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        other => return Err(format!("bad escape {other:?}")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a full utf-8 scalar
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| "invalid utf-8".to_string())?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        self.ws();
        let mut out = Vec::new();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                other => return Err(format!("bad array sep {other:?}")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        self.ws();
        let mut out = BTreeMap::new();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            out.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                other => return Err(format!("bad object sep {other:?}")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a":[1,2.5,-3e2],"b":"x\ny","c":true,"d":null}"#;
        let v = parse(src).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[1].as_f64(), Some(2.5));
        assert_eq!(v.get("b").unwrap().as_str(), Some("x\ny"));
        assert_eq!(v.get("c").unwrap().as_bool(), Some(true));
        let re = parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn nested_and_whitespace() {
        let src = "  { \"outer\" : { \"inner\" : [ [ ] , { } ] } }  ";
        let v = parse(src).unwrap();
        assert!(v.get("outer").unwrap().get("inner").is_some());
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(parse("{} x").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn unicode_escapes() {
        let v = parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str(), Some("Aé"));
    }

    #[test]
    fn numbers_precise() {
        let v = parse("[0.1, 1e-12, 123456789]").unwrap();
        let a = v.as_arr().unwrap();
        assert_eq!(a[0].as_f64(), Some(0.1));
        assert_eq!(a[1].as_f64(), Some(1e-12));
        assert_eq!(a[2].as_usize(), Some(123456789));
    }
}
