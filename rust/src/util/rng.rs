//! Deterministic PRNG: xoshiro256++ seeded through SplitMix64.
//!
//! Every stochastic piece of the system (data generation, topology
//! sampling, component sampling inside the algorithms) draws from this so
//! experiments are reproducible from a single `u64` seed, and so the
//! DSBA-vs-DSBA-s equivalence tests can replay identical sample paths.

/// xoshiro256++ generator (Blackman & Vigna), seeded via SplitMix64.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second normal variate from Box–Muller
    spare: Option<f64>,
}

#[inline]
fn rotl(x: u64, k: u32) -> u64 {
    (x << k) | (x >> (64 - k))
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Deterministic generator from a seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare: None }
    }

    /// Derive an independent stream (for per-node generators).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = rotl(self.s[0].wrapping_add(self.s[3]), 23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = rotl(self.s[3], 45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        // 53 random mantissa bits
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style rejection-free for our purposes (n << 2^64)
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Standard normal via Box–Muller (with caching).
    pub fn normal(&mut self) -> f64 {
        if let Some(v) = self.spare.take() {
            return v;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
            self.spare = Some(r * s);
            return r * c;
        }
    }

    /// Bernoulli(p).
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (Floyd's algorithm).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut chosen = std::collections::HashSet::with_capacity(k);
        let mut out = Vec::with_capacity(k);
        for j in n - k..n {
            let t = self.below(j + 1);
            let v = if chosen.contains(&t) { j } else { t };
            chosen.insert(v);
            out.push(v);
        }
        out
    }

    /// Geometric-ish power-law sample in [1, max]: used for per-row nnz
    /// distributions that mimic text-data long tails.
    pub fn zipf_nnz(&mut self, mean_nnz: f64, max: usize) -> usize {
        // log-normal around mean_nnz with sigma=0.6, clipped
        let mu = mean_nnz.max(1.0).ln() - 0.18; // e^{sigma^2/2} correction
        let x = (mu + 0.6 * self.normal()).exp();
        (x.round() as usize).clamp(1, max.max(1))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut r = Rng::new(7);
        let xs: Vec<f64> = (0..20_000).map(|_| r.uniform()).collect();
        assert!(xs.iter().all(|&x| (0.0..1.0).contains(&x)));
        let m = crate::util::mean(&xs);
        assert!((m - 0.5).abs() < 0.01, "mean {m}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let xs: Vec<f64> = (0..50_000).map(|_| r.normal()).collect();
        assert!(crate::util::mean(&xs).abs() < 0.02);
        assert!((crate::util::stddev(&xs) - 1.0).abs() < 0.02);
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(11);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let i = r.below(10);
            assert!(i < 10);
            seen[i] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(13);
        for _ in 0..50 {
            let s = r.sample_indices(20, 7);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), 7);
            assert!(s.iter().all(|&i| i < 20));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(17);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn forked_streams_differ() {
        let mut root = Rng::new(5);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let same = (0..32).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }
}
