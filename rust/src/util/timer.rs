//! Wall-clock timing helpers for the bench harness and perf logging.

use std::time::Instant;

/// Simple scope timer.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Timer { start: Instant::now() }
    }

    /// Elapsed seconds.
    pub fn secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Elapsed nanoseconds.
    pub fn nanos(&self) -> u128 {
        self.start.elapsed().as_nanos()
    }
}

/// Run `f` repeatedly until `min_time` seconds have elapsed (and at least
/// `min_iters` runs), returning per-iteration seconds statistics.
pub fn measure<F: FnMut()>(mut f: F, min_time: f64, min_iters: usize) -> Stats {
    let mut samples = Vec::new();
    let total = Timer::start();
    while samples.len() < min_iters || total.secs() < min_time {
        let t = Timer::start();
        f();
        samples.push(t.secs());
        if samples.len() > 10_000 {
            break;
        }
    }
    Stats::from(&samples)
}

/// Timing statistics (seconds).
#[derive(Clone, Debug)]
pub struct Stats {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p95: f64,
}

impl Stats {
    pub fn from(samples: &[f64]) -> Stats {
        let mut s = samples.to_vec();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Stats {
            n: s.len(),
            mean: crate::util::mean(&s),
            std: crate::util::stddev(&s),
            min: *s.first().unwrap_or(&0.0),
            p50: crate::util::percentile(&s, 50.0),
            p95: crate::util::percentile(&s, 95.0),
        }
    }

    /// Human-readable one-liner, auto-scaled units.
    pub fn display(&self) -> String {
        fn fmt(t: f64) -> String {
            if t < 1e-6 {
                format!("{:.1} ns", t * 1e9)
            } else if t < 1e-3 {
                format!("{:.2} µs", t * 1e6)
            } else if t < 1.0 {
                format!("{:.2} ms", t * 1e3)
            } else {
                format!("{:.3} s", t)
            }
        }
        format!(
            "mean {} (p50 {}, p95 {}, n={})",
            fmt(self.mean),
            fmt(self.p50),
            fmt(self.p95),
            self.n
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_collects_samples() {
        let st = measure(
            || {
                std::hint::black_box(1 + 1);
            },
            0.01,
            5,
        );
        assert!(st.n >= 5);
        assert!(st.mean >= 0.0);
        assert!(st.p95 >= st.p50);
    }
}
