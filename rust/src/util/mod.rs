//! Small self-contained substrates: RNG, JSON, timing.
//!
//! The build is fully offline against a minimal vendor set (no `rand`,
//! `serde`, `clap`, or `criterion`), so the pieces a normal project pulls
//! from crates.io are implemented here, sized to what the system needs.

pub mod rng;
pub mod json;
pub mod timer;

/// Mean of a slice (0.0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() { 0.0 } else { xs.iter().sum::<f64>() / xs.len() as f64 }
}

/// Population standard deviation of a slice.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Percentile (nearest-rank) of an unsorted slice.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!(!xs.is_empty(), "percentile of empty slice");
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((p / 100.0) * (v.len() as f64 - 1.0)).round() as usize;
    v[rank.min(v.len() - 1)]
}
