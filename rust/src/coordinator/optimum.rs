//! Reference-optimum pre-solve: every figure plots distance to `z*`, so
//! we need it to much higher accuracy than any compared method reaches.
//!
//! Strategy: run centralized Point-SAGA (single-node DSBA, Remark 5.1) on
//! the pooled dataset until the *global* operator residual
//! `||sum_n B_n^lambda(z)||` is below tolerance, then polish with a
//! full-operator iteration chosen by capability:
//!
//! * gradient problems — damped Picard (proximal-gradient when an l1
//!   term is declared), safe for cocoercive operators;
//! * problems declaring a [`crate::operators::SaddleStructure`] —
//!   **extragradient** (Korpelevich): the saddle operator is monotone
//!   but not cocoercive, so a plain Picard step must shrink to
//!   `mu / L^2` (vanishing for ill-conditioned saddles), while the
//!   extragradient step contracts at `~1/(2L)` for any strongly
//!   monotone Lipschitz operator. This replaces the AUC-only polish:
//!   every saddle registry entry (AUC, robust-ls, dro-bilinear) shares
//!   it.

use crate::algorithms::{AlgoParams, PointSaga};
use crate::data::Partition;
use crate::operators::{l1_kkt_residual, Problem};
use crate::solvers::soft_threshold;
use std::sync::Arc;

/// Build the pooled single-node twin of a problem via
/// [`Problem::rebuild`] (same hyper-parameters, pooled partition). The
/// global root is unchanged: `sum_n (B_n + lambda I)(z) = 0` iff
/// `(B_pooled + lambda I)(z) = 0`, and a per-component l1 term carries
/// over with the same weight (component means preserve it).
fn pooled_twin(p: &dyn Problem) -> Arc<dyn Problem> {
    let pooled = p.partition().pooled();
    p.rebuild(Partition::equal_random(&pooled, 1, 0))
}

/// Solve the root-finding problem to `global_residual(z) <= tol` (the
/// KKT inclusion residual for problems with an l1 term).
pub fn solve_optimum(p: &dyn Problem, tol: f64) -> Vec<f64> {
    let twin = pooled_twin(p);
    let (l, mu) = twin.l_mu();
    // Point-SAGA step from its theory (1/3L is safe; larger often fine)
    let alpha = 1.0 / (2.0 * l.max(1e-12));
    let mut params = AlgoParams::new(alpha, twin.dim(), 0x0971_u64 ^ 0x517a);
    params.z0 = vec![0.0; twin.dim()];
    let mut solver = PointSaga::new(twin.clone(), &params);
    let q_total = twin.q();
    // residual checked on the ORIGINAL problem scaling: residuals differ
    // by the factor N (pooled mean vs sum) — solve to tol / N for safety
    let n_factor = p.nodes() as f64;
    let inner_tol = tol / n_factor.max(1.0) * 0.5;
    let (mut z, _) = solver.solve_to_residual(inner_tol, 4 * q_total, 3000 * q_total);

    let l1 = twin.l1_weight();
    if twin.saddle().is_some() && l1 == 0.0 {
        // extragradient polish for saddle entries: z_half = z - s G(z),
        // z <- z - s G(z_half), linearly convergent for strongly
        // monotone L-Lipschitz operators at s = 1/(2L)
        let step = 1.0 / (2.0 * l.max(1e-12));
        let mut g = vec![0.0; twin.dim()];
        let mut gh = vec![0.0; twin.dim()];
        let mut half = vec![0.0; twin.dim()];
        for _ in 0..2000 {
            twin.full_operator(0, &z, &mut g);
            if crate::linalg::norm2(&g) * n_factor <= tol {
                break;
            }
            half.copy_from_slice(&z);
            crate::linalg::axpy(-step, &g, &mut half);
            twin.full_operator(0, &half, &mut gh);
            crate::linalg::axpy(-step, &gh, &mut z);
        }
        return z;
    }

    // polish: damped full-operator (Picard) iterations on the pooled
    // twin, safe for cocoercive (gradient-field) operators with step
    // < 2 mu / L^2. With an l1 term the smooth part is a gradient field
    // and the Picard step becomes proximal-gradient: the soft-threshold
    // resolvent absorbs the nonsmooth term exactly.
    let step = (mu / (l * l)).min(1.0 / l);
    let mut g = vec![0.0; twin.dim()];
    for _ in 0..2000 {
        twin.full_operator(0, &z, &mut g);
        let r = l1_kkt_residual(&z, &g, l1) * n_factor;
        if r <= tol {
            break;
        }
        if l1 == 0.0 {
            crate::linalg::axpy(-step, &g, &mut z);
        } else {
            for (zk, gk) in z.iter_mut().zip(&g) {
                *zk = soft_threshold(*zk - step * gk, step * l1);
            }
        }
    }
    z
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SyntheticSpec;
    use crate::operators::{AucProblem, LogisticProblem, RidgeProblem};

    #[test]
    fn ridge_optimum_residual_small() {
        let ds = SyntheticSpec::tiny().with_regression(true).generate(71);
        let p = RidgeProblem::new(ds.partition_seeded(4, 3), 0.05);
        let z = solve_optimum(&p, 1e-10);
        assert!(p.global_residual(&z) < 1e-9);
    }

    #[test]
    fn ridge_optimum_matches_normal_equations() {
        // cross-check against an explicit dense solve of
        // (sum (1/q) A^T A + N lambda I) z = sum (1/q) A^T y
        let ds = SyntheticSpec::tiny()
            .with_samples(40)
            .with_dim(12)
            .with_regression(true)
            .generate(72);
        let lam = 0.1;
        let p = RidgeProblem::new(ds.partition_seeded(2, 3), lam);
        let z = solve_optimum(&p, 1e-12);
        let d = p.dim();
        let part = p.partition();
        let mut a_mat = crate::linalg::DenseMatrix::zeros(d, d);
        let mut rhs = vec![0.0; d];
        for n in 0..part.nodes() {
            let shard = &part.shards[n];
            let inv_q = 1.0 / part.q as f64;
            for i in 0..shard.rows {
                let row = shard.row_sparse(i);
                for (&ji, &vi) in row.idx.iter().zip(&row.val) {
                    for (&jj, &vj) in row.idx.iter().zip(&row.val) {
                        a_mat[(ji as usize, jj as usize)] += inv_q * vi * vj;
                    }
                    rhs[ji as usize] += inv_q * vi * part.labels[n][i];
                }
            }
        }
        for k in 0..d {
            a_mat[(k, k)] += part.nodes() as f64 * lam;
        }
        let z_exact = a_mat.solve(&rhs).unwrap();
        let err: f64 = z
            .iter()
            .zip(&z_exact)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f64>()
            .sqrt();
        assert!(err < 1e-8, "err {err}");
    }

    #[test]
    fn logistic_optimum_residual_small() {
        let ds = SyntheticSpec::tiny().generate(73);
        let p = LogisticProblem::new(ds.partition_seeded(3, 3), 0.05);
        let z = solve_optimum(&p, 1e-9);
        assert!(p.global_residual(&z) < 1e-8);
    }

    #[test]
    fn auc_optimum_residual_small() {
        let ds = SyntheticSpec::tiny().generate(74);
        let p = AucProblem::new(ds.partition_seeded(3, 3), 0.05);
        let z = solve_optimum(&p, 1e-8);
        assert!(p.global_residual(&z) < 1e-7);
    }

    #[test]
    fn robust_ls_optimum_residual_small() {
        // the extragradient polish path (saddle entry, no l1)
        use crate::operators::RobustLsProblem;
        let ds = SyntheticSpec::tiny().with_regression(true).generate(75);
        let p = RobustLsProblem::new(ds.partition_seeded(3, 3), 0.05, 2.0);
        let z = solve_optimum(&p, 1e-9);
        assert!(p.global_residual(&z) < 1e-8);
    }

    #[test]
    fn dro_bilinear_optimum_residual_small() {
        use crate::operators::DroBilinearProblem;
        let ds = SyntheticSpec::tiny().generate(76);
        let p = DroBilinearProblem::new(ds.partition_seeded(3, 3), 0.05, 1.0);
        let z = solve_optimum(&p, 1e-9);
        assert!(p.global_residual(&z) < 1e-8);
    }

    #[test]
    fn restricted_gap_vanishes_at_the_saddle_point() {
        // gap(z*) == 0 up to rounding, gap > 0 away from it, and the
        // primal/dual hybrid evaluation uses the declared split
        use crate::coordinator::restricted_gap;
        use crate::operators::RobustLsProblem;
        let ds = SyntheticSpec::tiny().with_regression(true).generate(77);
        let p = RobustLsProblem::new(ds.partition_seeded(3, 3), 0.05, 2.0);
        let z_star = solve_optimum(&p, 1e-11);
        let s = p.saddle().unwrap();
        let at_star = restricted_gap(&p, &s, &z_star, &z_star).unwrap();
        assert!(at_star.abs() < 1e-12, "gap at z*: {at_star}");
        let mut z = z_star.clone();
        for v in z.iter_mut() {
            *v += 0.1;
        }
        let away = restricted_gap(&p, &s, &z, &z_star).unwrap();
        assert!(away > 1e-6, "gap away from z*: {away}");
    }
}
