//! Experiment coordinator: wires problem + topology + algorithm +
//! network together, drives synchronous rounds, pre-solves the reference
//! optimum, and samples the paper's metrics.

mod optimum;
mod lyapunov;

pub use lyapunov::LyapunovProbe;
pub use optimum::solve_optimum;

use crate::algorithms::{self, AlgoParams, Algorithm, AlgorithmKind};
use crate::comm::{CommCostModel, CompressionSpec, Network};
use crate::graph::{MixingMatrix, Topology};
use crate::metrics::{auc_score, suboptimality, GlobalStats, MetricsRow};
use crate::operators::{Problem, SaddleStat, SaddleStructure};
use crate::runtime::transport::{tcp_from_spec, LocalTransport};
use crate::runtime::{
    EngineKind, EngineSpec, FaultSpec, ModeSpec, ParallelEngine, TcpSpec, TransportKind,
};
use crate::telemetry::TelemetrySpec;
use crate::util::timer::Timer;
use std::sync::Arc;

/// A full experiment run: one (problem, topology, algorithm) triple.
/// Constructed through [`ExperimentBuilder`] (`Experiment::builder`).
pub struct Experiment {
    pub problem: Arc<dyn Problem>,
    pub topo: Topology,
    pub mix: MixingMatrix,
    pub kind: AlgorithmKind,
    pub params: AlgoParams,
    pub cost_model: CommCostModel,
    /// stop after this many effective passes
    pub passes_target: f64,
    /// number of metric samples to record across the run
    pub record_points: usize,
    /// reference optimum (pre-solved lazily if absent)
    pub z_star: Option<Vec<f64>>,
    /// hard cap on rounds (safety)
    pub max_rounds: usize,
    /// execution engine: round driver, threads, transport, endpoints
    pub engine: EngineSpec,
}

/// Builder for [`Experiment`]: the only construction path, so every
/// layer (config JSON, CLI flags, benches, tests) assembles runs with
/// the same typed vocabulary — engine and transport options travel as
/// one [`EngineSpec`] instead of loose strings.
pub struct ExperimentBuilder {
    exp: Experiment,
}

impl ExperimentBuilder {
    pub fn step_size(mut self, alpha: f64) -> Self {
        self.exp.params.alpha = alpha;
        self
    }

    pub fn passes(mut self, p: f64) -> Self {
        self.exp.passes_target = p;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.exp.params.seed = seed;
        self
    }

    pub fn cost_model(mut self, c: CommCostModel) -> Self {
        self.exp.cost_model = c;
        self
    }

    /// Supply a pre-solved reference optimum (skips the lazy pre-solve).
    pub fn z_star(mut self, z: Vec<f64>) -> Self {
        self.exp.z_star = Some(z);
        self
    }

    pub fn record_points(mut self, n: usize) -> Self {
        self.exp.record_points = n;
        self
    }

    pub fn max_rounds(mut self, n: usize) -> Self {
        self.exp.max_rounds = n;
        self
    }

    pub fn mixing(mut self, mix: MixingMatrix) -> Self {
        self.exp.mix = mix;
        self
    }

    pub fn params<F: FnOnce(&mut AlgoParams)>(mut self, f: F) -> Self {
        f(&mut self.exp.params);
        self
    }

    /// Full engine configuration in one value.
    pub fn engine(mut self, spec: EngineSpec) -> Self {
        self.exp.engine = spec;
        self
    }

    /// Select the round driver (and worker count for the parallel one;
    /// `threads = 0` = all available cores, capped by node count).
    pub fn engine_kind(mut self, kind: EngineKind, threads: usize) -> Self {
        self.exp.engine.kind = kind;
        self.exp.engine.threads = threads;
        self
    }

    /// Select the parallel engine's edge-channel backend.
    pub fn transport(mut self, transport: TransportKind) -> Self {
        self.exp.engine.transport = transport;
        self
    }

    /// Wire compression at the transport boundary (parallel engine
    /// only — the sequential oracle is always the uncompressed
    /// reference, and `try_run` rejects the combination).
    pub fn compress(mut self, spec: CompressionSpec) -> Self {
        self.exp.engine.compress = spec;
        self
    }

    /// Round clock for the parallel engine: barrier-synced
    /// [`ModeSpec::Sync`] (the default) or bounded-staleness
    /// [`ModeSpec::Async`]. The sequential oracle is synchronous by
    /// definition, and `try_run` rejects the combination; so is a
    /// split-hosted TCP run (async requires hosting every node).
    pub fn mode(mut self, mode: ModeSpec) -> Self {
        self.exp.engine.mode = mode;
        self
    }

    /// TCP endpoint configuration for `TransportKind::Tcp`: listen
    /// address ("" = ephemeral loopback), `node=host:port` peers spec,
    /// and hosted-node spec ("" = host everything — the single-process
    /// loopback mode). A partial `hosted` splits the run across engine
    /// processes; this process then reports metrics for its share only.
    pub fn tcp(mut self, tcp: TcpSpec) -> Self {
        self.exp.engine.tcp = tcp;
        self
    }

    /// Fault-injection plan for the parallel engine (`--fault`): link
    /// drop/dup faults additionally require the TCP transport's reliable
    /// link layer, and the sequential oracle — the fault-free reference
    /// — rejects any plan outright in `try_run`.
    pub fn fault(mut self, fault: FaultSpec) -> Self {
        self.exp.engine.fault = fault;
        self
    }

    /// Per-round JSONL telemetry stream for the parallel engine
    /// (`--telemetry`); the sequential oracle rejects it in `try_run`.
    pub fn telemetry(mut self, telemetry: TelemetrySpec) -> Self {
        self.exp.engine.telemetry = telemetry;
        self
    }

    pub fn build(self) -> Experiment {
        self.exp
    }
}

impl Experiment {
    pub fn builder<P: Problem + 'static>(
        problem: P,
        topo: Topology,
        kind: AlgorithmKind,
    ) -> ExperimentBuilder {
        Self::builder_from_arc(Arc::new(problem), topo, kind)
    }

    pub fn builder_from_arc(
        problem: Arc<dyn Problem>,
        topo: Topology,
        kind: AlgorithmKind,
    ) -> ExperimentBuilder {
        assert_eq!(problem.nodes(), topo.n, "partition/topology node mismatch");
        let mix = MixingMatrix::laplacian(&topo, 1.0);
        let params = AlgoParams::new(0.5, problem.dim(), 0xa15e);
        ExperimentBuilder {
            exp: Experiment {
                problem,
                topo,
                mix,
                kind,
                params,
                cost_model: CommCostModel::default(),
                passes_target: 20.0,
                record_points: 40,
                z_star: None,
                max_rounds: usize::MAX,
                engine: EngineSpec::default(),
            },
        }
    }

    /// Pre-solve the reference optimum if not supplied.
    pub fn ensure_z_star(&mut self) -> &[f64] {
        if self.z_star.is_none() {
            self.z_star = Some(solve_optimum(self.problem.as_ref(), 1e-11));
        }
        self.z_star.as_ref().unwrap()
    }

    /// Rounds needed to reach the passes target for this method.
    pub fn rounds_for_target(&self) -> usize {
        let per_round = if self.kind.is_stochastic() {
            1.0 / self.problem.q() as f64
        } else {
            1.0
        };
        ((self.passes_target / per_round).ceil() as usize).max(1)
    }

    /// Run to the passes target, sampling metrics along the way.
    /// Panics on transport setup failure — use [`Experiment::try_run`]
    /// where a recoverable error path is needed (the CLI does).
    pub fn run(&mut self) -> Trace {
        self.try_run().unwrap_or_else(|e| panic!("{e}"))
    }

    /// Fallible variant of [`Experiment::run`]: operational transport
    /// failures (port in use, peer unreachable, handshake timeout)
    /// surface as `Err` instead of a panic.
    pub fn try_run(&mut self) -> Result<Trace, String> {
        if self.engine.kind == EngineKind::Sequential
            && self.engine.compress != CompressionSpec::None
        {
            return Err(format!(
                "--compress {} requires the parallel engine; the sequential \
                 oracle is the uncompressed reference",
                self.engine.compress.name()
            ));
        }
        if self.engine.kind == EngineKind::Sequential && self.engine.mode.is_async() {
            return Err(format!(
                "--mode {} requires the parallel engine; the sequential \
                 oracle is synchronous by definition",
                self.engine.mode.name()
            ));
        }
        if self.engine.kind == EngineKind::Sequential && !self.engine.fault.is_none() {
            return Err(format!(
                "--fault {} requires the parallel engine; the sequential \
                 oracle is the fault-free reference",
                self.engine.fault.name()
            ));
        }
        if self.engine.kind == EngineKind::Sequential && self.engine.telemetry.enabled() {
            return Err(
                "--telemetry requires the parallel engine; the sequential \
                 oracle emits no per-round telemetry"
                    .to_string(),
            );
        }
        if self.engine.fault.link_faults() && self.engine.transport != TransportKind::Tcp {
            return Err(format!(
                "--fault {} injects link faults (drop/dup), which need the \
                 TCP transport's reliable link layer; add --transport tcp",
                self.engine.fault.name()
            ));
        }
        self.ensure_z_star();
        let z_star = self.z_star.clone().unwrap();
        // set when a TCP transport hosts only part of the node set: the
        // remote rows never move, so metrics must cover our share only
        let mut hosted_rows: Option<Vec<usize>> = None;
        let mut alg: Box<dyn Algorithm> = match self.engine.kind {
            EngineKind::Sequential => algorithms::build(
                self.kind,
                self.problem.clone(),
                &self.mix,
                &self.topo,
                &self.params,
            ),
            EngineKind::Parallel => match self.engine.transport {
                TransportKind::Local => Box::new(ParallelEngine::new_faulted(
                    self.kind,
                    self.problem.clone(),
                    &self.mix,
                    &self.topo,
                    &self.params,
                    self.engine.threads,
                    Box::new(LocalTransport::new(self.topo.n)),
                    &self.engine.compress,
                    self.engine.mode,
                    &self.engine.fault,
                    &self.engine.telemetry,
                )?),
                TransportKind::Tcp => {
                    use crate::runtime::Transport;
                    let transport = tcp_from_spec(
                        &self.topo,
                        self.params.seed,
                        &self.engine.tcp.hosted,
                        &self.engine.tcp.listen,
                        &self.engine.tcp.peers,
                    )
                    .map_err(|e| format!("tcp transport setup failed: {e}"))?;
                    if self.engine.mode.is_async()
                        && transport.hosted().len() < self.topo.n
                    {
                        return Err(format!(
                            "--mode {} requires hosting every node ({} of {} \
                             hosted) — split-hosted runs are sync-only",
                            self.engine.mode.name(),
                            transport.hosted().len(),
                            self.topo.n
                        ));
                    }
                    let eng = ParallelEngine::new_faulted(
                        self.kind,
                        self.problem.clone(),
                        &self.mix,
                        &self.topo,
                        &self.params,
                        self.engine.threads,
                        Box::new(transport),
                        &self.engine.compress,
                        self.engine.mode,
                        &self.engine.fault,
                        &self.engine.telemetry,
                    )?;
                    if eng.hosted().len() < self.topo.n {
                        hosted_rows = Some(eng.hosted().to_vec());
                    }
                    Box::new(eng)
                }
            },
        };
        let mut net = Network::new(self.topo.clone(), self.cost_model);
        let total_rounds = self.rounds_for_target().min(self.max_rounds);
        let stride = (total_rounds / self.record_points.max(1)).max(1);
        let timer = Timer::start();
        let mut rows = Vec::new();
        let hosted = hosted_rows;
        // the stepping loop runs under catch_unwind so engine poisoning —
        // a transport failure or an injected kill fault surfacing through
        // the launcher — comes back as a named Err, not a panic
        let stepped = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            rows.push(self.sample(
                alg.as_mut(),
                &net,
                &z_star,
                timer.secs(),
                hosted.as_deref(),
            ));
            let mut round = 0;
            // split-hosted processes must run the exact same number of
            // rounds (they are socket-lockstepped), so the share-local
            // passes() early-exit — which can diverge across processes
            // for inner-solver methods — is disabled; total_rounds is
            // computed identically from the shared config on every
            // process. The same lockstep makes the per-sample stats
            // exchange safe: every process samples at identical rounds.
            let split = hosted.is_some();
            while round < total_rounds && (split || alg.passes() < self.passes_target) {
                alg.step(&mut net);
                round += 1;
                if round % stride == 0 || round == total_rounds {
                    rows.push(self.sample(
                        alg.as_mut(),
                        &net,
                        &z_star,
                        timer.secs(),
                        hosted.as_deref(),
                    ));
                }
            }
        }));
        if let Err(payload) = stepped {
            let why = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "a worker panicked".to_string());
            return Err(format!("run failed: {why}"));
        }
        // read before the engine drops (dropping joins the writer thread)
        let telemetry_dropped = alg.telemetry_dropped();
        Ok(Trace { method: self.kind, rows, z_star, telemetry_dropped })
    }

    fn sample(
        &self,
        alg: &mut dyn Algorithm,
        net: &Network,
        z_star: &[f64],
        wall: f64,
        hosted: Option<&[usize]>,
    ) -> MetricsRow {
        // split-hosted runs: piggyback per-node stat rows on the
        // transport's end-of-round control channel so the reported
        // series is global, not a per-process share
        if hosted.is_some() {
            let received: Vec<f64> =
                (0..self.topo.n).map(|m| net.received_by(m)).collect();
            let received_bytes: Vec<f64> =
                (0..self.topo.n).map(|m| net.bytes_received_by(m)).collect();
            if let Some(gs) = alg.global_stats(&received, &received_bytes) {
                return global_metrics_row(
                    self.problem.as_ref(),
                    &gs,
                    z_star,
                    alg.iteration(),
                    wall,
                );
            }
        }
        let iter = alg.iteration();
        let passes = alg.passes();
        let all = alg.iterates();
        // defensive fallback: a driver without a stats exchange scores
        // its own share only
        let hosted_view: Vec<Vec<f64>>;
        let zs: &[Vec<f64>] = match hosted {
            Some(rows) => {
                hosted_view = rows.iter().map(|&n| all[n].clone()).collect();
                &hosted_view
            }
            None => all,
        };
        let comm = match hosted {
            Some(rows) => rows.iter().map(|&n| net.received_by(n)).fold(0.0, f64::max),
            None => net.max_received(),
        };
        let comm_bytes = match hosted {
            Some(rows) => {
                rows.iter().map(|&n| net.bytes_received_by(n)).fold(0.0, f64::max)
            }
            None => net.max_received_bytes(),
        };
        metrics_row_from(
            self.problem.as_ref(),
            zs,
            z_star,
            iter,
            passes,
            comm,
            comm_bytes,
            wall,
            alg.staleness_stats(),
        )
    }
}

/// Assemble one metrics row from a complete iterate set — the shared
/// core of local sampling and split-run aggregation. Branches on the
/// problem's declared [`SaddleStructure`] (never on `auc_metric()`): a
/// saddle split turns on the residual and restricted-gap series, and
/// only `SaddleStat::AucRanking` turns on the ranking statistic.
#[allow(clippy::too_many_arguments)]
fn metrics_row_from(
    problem: &dyn Problem,
    zs: &[Vec<f64>],
    z_star: &[f64],
    iter: usize,
    passes: f64,
    comm_doubles: f64,
    comm_bytes: f64,
    wall: f64,
    staleness: (u64, u64),
) -> MetricsRow {
    let avg = average_iterate(zs);
    let saddle = problem.saddle();
    MetricsRow {
        iter,
        passes,
        comm_doubles,
        comm_bytes,
        suboptimality: suboptimality(zs, z_star),
        objective: problem.objective(&avg).unwrap_or(f64::NAN),
        auc: if saddle.is_some_and(|s| s.stat == SaddleStat::AucRanking) {
            auc_score(problem.partition(), &avg)
        } else {
            f64::NAN
        },
        saddle_res: if saddle.is_some() {
            problem.global_residual(&avg)
        } else {
            f64::NAN
        },
        saddle_gap: match saddle {
            Some(s) => restricted_gap(problem, &s, &avg, z_star).unwrap_or(f64::NAN),
            None => f64::NAN,
        },
        wall_secs: wall,
        max_staleness: staleness.0,
        stalls: staleness.1,
    }
}

/// Restricted duality gap `L(x, y*) - L(x*, y)`: nonnegative by the
/// saddle-point property of `(x*, y*)`, zero exactly at the saddle
/// point, and O(||z - z*||) for smooth couplings — so it inherits
/// DSBA's geometric rate. `None` when the problem does not expose
/// [`Problem::saddle_value`].
pub fn restricted_gap(
    problem: &dyn Problem,
    s: &SaddleStructure,
    z: &[f64],
    z_star: &[f64],
) -> Option<f64> {
    let pd = s.primal_dims;
    let mut x_ystar = z.to_vec();
    x_ystar[pd..].copy_from_slice(&z_star[pd..]);
    let mut xstar_y = z_star.to_vec();
    xstar_y[pd..].copy_from_slice(&z[pd..]);
    Some(problem.saddle_value(&x_ystar)? - problem.saddle_value(&xstar_y)?)
}

/// Metrics row of a split-hosted run, computed from the aggregated
/// global stat rows (every node's iterate, eval count and received
/// DOUBLEs — sorted by node index). Identical arithmetic to the
/// single-process path, so a split run's series is bit-for-bit the
/// sequential oracle's.
pub fn global_metrics_row(
    problem: &dyn Problem,
    gs: &GlobalStats,
    z_star: &[f64],
    iter: usize,
    wall: f64,
) -> MetricsRow {
    assert_eq!(
        gs.rows.len(),
        problem.nodes(),
        "split-run metrics aggregation incomplete: {} of {} node rows",
        gs.rows.len(),
        problem.nodes()
    );
    let zs: Vec<Vec<f64>> = gs.rows.iter().map(|r| r.z.clone()).collect();
    let comm = gs.rows.iter().map(|r| r.received).fold(0.0, f64::max);
    let comm_bytes = gs.rows.iter().map(|r| r.received_bytes).fold(0.0, f64::max);
    let evals: u64 = gs.rows.iter().map(|r| r.evals).sum();
    // split-hosted runs are sync-only, so staleness is zero by
    // construction
    metrics_row_from(
        problem,
        &zs,
        z_star,
        iter,
        evals as f64 / gs.pass_denom,
        comm,
        comm_bytes,
        wall,
        (0, 0),
    )
}

/// Node-averaged iterate (metrics evaluation point).
pub fn average_iterate(zs: &[Vec<f64>]) -> Vec<f64> {
    let n = zs.len() as f64;
    let mut avg = vec![0.0; zs[0].len()];
    for z in zs {
        crate::linalg::axpy(1.0 / n, z, &mut avg);
    }
    avg
}

/// Result of an experiment run.
#[derive(Clone, Debug)]
pub struct Trace {
    pub method: AlgorithmKind,
    pub rows: Vec<MetricsRow>,
    pub z_star: Vec<f64>,
    /// Rows the telemetry writer's wait-free channel dropped during the
    /// run (`None` when the run carried no telemetry). Nonzero means the
    /// JSONL stream under-reports the run and must be read accordingly.
    pub telemetry_dropped: Option<u64>,
}

impl Trace {
    pub fn last_suboptimality(&self) -> f64 {
        self.rows.last().map(|r| r.suboptimality).unwrap_or(f64::NAN)
    }

    pub fn last_auc(&self) -> f64 {
        self.rows.last().map(|r| r.auc).unwrap_or(f64::NAN)
    }

    /// Final saddle residual (NaN for non-saddle problems).
    pub fn last_saddle_res(&self) -> f64 {
        self.rows.last().map(|r| r.saddle_res).unwrap_or(f64::NAN)
    }

    pub fn final_comm(&self) -> f64 {
        self.rows.last().map(|r| r.comm_doubles).unwrap_or(0.0)
    }

    /// Final declared bytes-on-wire received by the hottest node.
    pub fn final_comm_bytes(&self) -> f64 {
        self.rows.last().map(|r| r.comm_bytes).unwrap_or(0.0)
    }

    /// First recorded pass count at which suboptimality <= tol
    /// (None if never reached) — the "iterations to epsilon" of Table 1.
    pub fn passes_to_tol(&self, tol: f64) -> Option<f64> {
        self.rows.iter().find(|r| r.suboptimality <= tol).map(|r| r.passes)
    }

    /// Comm doubles spent when suboptimality first hits tol.
    pub fn comm_to_tol(&self, tol: f64) -> Option<f64> {
        self.rows
            .iter()
            .find(|r| r.suboptimality <= tol)
            .map(|r| r.comm_doubles)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SyntheticSpec;
    use crate::operators::RidgeProblem;

    #[test]
    fn experiment_runs_and_converges() {
        let ds = SyntheticSpec::tiny().with_regression(true).generate(61);
        let part = ds.partition_seeded(4, 3);
        let topo = Topology::erdos_renyi(4, 0.6, 5);
        let mut exp = Experiment::builder(RidgeProblem::new(part, 0.05), topo, AlgorithmKind::Dsba)
            .step_size(0.5)
            .passes(40.0)
            .record_points(10)
            .build();
        let trace = exp.run();
        assert!(trace.rows.len() >= 10);
        assert!(
            trace.last_suboptimality() < 1e-8,
            "subopt {}",
            trace.last_suboptimality()
        );
        // suboptimality decreases overall
        assert!(trace.rows[0].suboptimality > trace.last_suboptimality());
        // comm monotone nondecreasing
        for w in trace.rows.windows(2) {
            assert!(w[1].comm_doubles >= w[0].comm_doubles);
        }
    }

    #[test]
    fn parallel_engine_reproduces_sequential_trace() {
        let ds = SyntheticSpec::tiny().with_regression(true).generate(61);
        let topo = Topology::erdos_renyi(4, 0.6, 5);
        let z_star = {
            let p = RidgeProblem::new(ds.partition_seeded(4, 3), 0.05);
            solve_optimum(&p, 1e-11)
        };
        let run = |engine: EngineKind| {
            let part = ds.partition_seeded(4, 3);
            let mut exp =
                Experiment::builder(RidgeProblem::new(part, 0.05), topo.clone(), AlgorithmKind::Dsba)
                    .step_size(0.5)
                    .passes(8.0)
                    .record_points(8)
                    .z_star(z_star.clone())
                    .engine_kind(engine, 2)
                    .build();
            exp.run()
        };
        let seq = run(EngineKind::Sequential);
        let par = run(EngineKind::Parallel);
        assert_eq!(seq.rows.len(), par.rows.len());
        for (a, b) in seq.rows.iter().zip(&par.rows) {
            // identical sampling rounds, identical iterates -> identical metrics
            assert_eq!(a.iter, b.iter);
            assert_eq!(a.suboptimality, b.suboptimality);
            assert_eq!(a.comm_doubles, b.comm_doubles);
        }
    }

    #[test]
    fn tcp_transport_reproduces_sequential_trace_through_config() {
        let ds = SyntheticSpec::tiny().with_regression(true).generate(61);
        let topo = Topology::erdos_renyi(4, 0.6, 5);
        let z_star = {
            let p = RidgeProblem::new(ds.partition_seeded(4, 3), 0.05);
            solve_optimum(&p, 1e-11)
        };
        let run = |engine: EngineKind, transport: TransportKind| {
            let part = ds.partition_seeded(4, 3);
            let mut exp =
                Experiment::builder(RidgeProblem::new(part, 0.05), topo.clone(), AlgorithmKind::Dsba)
                    .step_size(0.5)
                    .passes(6.0)
                    .record_points(6)
                    .z_star(z_star.clone())
                    .engine_kind(engine, 2)
                    .transport(transport)
                    .build();
            exp.run()
        };
        let seq = run(EngineKind::Sequential, TransportKind::Local);
        let tcp = run(EngineKind::Parallel, TransportKind::Tcp);
        assert_eq!(seq.rows.len(), tcp.rows.len());
        for (a, b) in seq.rows.iter().zip(&tcp.rows) {
            assert_eq!(a.iter, b.iter);
            assert_eq!(a.suboptimality, b.suboptimality);
            assert_eq!(a.comm_doubles, b.comm_doubles);
        }
    }

    #[test]
    fn builder_compression_reduces_reported_bytes() {
        let ds = SyntheticSpec::tiny().with_regression(true).generate(61);
        let topo = Topology::erdos_renyi(4, 0.6, 5);
        let z_star = {
            let p = RidgeProblem::new(ds.partition_seeded(4, 3), 0.05);
            solve_optimum(&p, 1e-11)
        };
        let run = |spec: CompressionSpec| {
            let part = ds.partition_seeded(4, 3);
            let mut exp = Experiment::builder(
                RidgeProblem::new(part, 0.05),
                topo.clone(),
                crate::algorithms::AlgorithmKind::Extra,
            )
            .step_size(0.5)
            .passes(6.0)
            .record_points(6)
            .z_star(z_star.clone())
            .engine_kind(EngineKind::Parallel, 2)
            .compress(spec)
            .build();
            exp.run()
        };
        let dense = run(CompressionSpec::None);
        let topk = run(CompressionSpec::TopK(4));
        assert_eq!(dense.rows.len(), topk.rows.len());
        // same DOUBLE-model schedule, strictly fewer declared wire bytes
        assert!(topk.final_comm_bytes() > 0.0);
        assert!(
            topk.final_comm_bytes() < dense.final_comm_bytes(),
            "topk moved {} bytes, dense moved {}",
            topk.final_comm_bytes(),
            dense.final_comm_bytes()
        );
        // bytes accumulate monotonically like the DOUBLE series
        for w in dense.rows.windows(2) {
            assert!(w[1].comm_bytes >= w[0].comm_bytes);
        }
        // the sequential oracle rejects compression outright
        let mut seq = Experiment::builder(
            RidgeProblem::new(ds.partition_seeded(4, 3), 0.05),
            topo,
            crate::algorithms::AlgorithmKind::Extra,
        )
        .compress(CompressionSpec::TopK(2))
        .build();
        let err = seq.try_run().unwrap_err();
        assert!(err.contains("parallel"), "{err}");
    }

    #[test]
    fn builder_async_mode_runs_and_rejects_misuse() {
        let ds = SyntheticSpec::tiny().with_regression(true).generate(61);
        let topo = Topology::erdos_renyi(4, 0.6, 5);
        let z_star = {
            let p = RidgeProblem::new(ds.partition_seeded(4, 3), 0.05);
            solve_optimum(&p, 1e-11)
        };
        // parallel + async:0 reproduces the sequential trace exactly
        let run = |kind: EngineKind, mode: ModeSpec| {
            let part = ds.partition_seeded(4, 3);
            let mut exp = Experiment::builder(
                RidgeProblem::new(part, 0.05),
                topo.clone(),
                AlgorithmKind::Dsba,
            )
            .step_size(0.5)
            .passes(6.0)
            .record_points(6)
            .z_star(z_star.clone())
            .engine_kind(kind, 2)
            .mode(mode)
            .build();
            exp.run()
        };
        let seq = run(EngineKind::Sequential, ModeSpec::Sync);
        let asy = run(EngineKind::Parallel, ModeSpec::Async(0));
        assert_eq!(seq.rows.len(), asy.rows.len());
        for (a, b) in seq.rows.iter().zip(&asy.rows) {
            assert_eq!(a.iter, b.iter);
            assert_eq!(a.suboptimality, b.suboptimality);
            assert_eq!(a.comm_doubles, b.comm_doubles);
            assert_eq!(b.max_staleness, 0, "async:0 consumed stale data");
        }
        // the sequential oracle rejects async outright
        let mut bad = Experiment::builder(
            RidgeProblem::new(ds.partition_seeded(4, 3), 0.05),
            topo,
            AlgorithmKind::Dsba,
        )
        .mode(ModeSpec::Async(1))
        .build();
        let err = bad.try_run().unwrap_err();
        assert!(err.contains("parallel"), "{err}");
    }

    #[test]
    fn fault_and_telemetry_guardrails() {
        let ds = SyntheticSpec::tiny().with_regression(true).generate(61);
        let topo = Topology::erdos_renyi(4, 0.6, 5);
        let mk = |f: &str, kind: EngineKind, transport: TransportKind| {
            let mut exp = Experiment::builder(
                RidgeProblem::new(ds.partition_seeded(4, 3), 0.05),
                topo.clone(),
                AlgorithmKind::Dsba,
            )
            .engine_kind(kind, 2)
            .transport(transport)
            .fault(FaultSpec::parse(f).unwrap())
            .build();
            exp.try_run().err()
        };
        // the sequential oracle is the fault-free reference
        let e = mk("drop:0.1", EngineKind::Sequential, TransportKind::Local).unwrap();
        assert!(e.contains("parallel"), "{e}");
        // link faults need the TCP link layer
        let e = mk("drop:0.1", EngineKind::Parallel, TransportKind::Local).unwrap();
        assert!(e.contains("tcp"), "{e}");
        // telemetry on the sequential oracle is rejected too
        let mut exp = Experiment::builder(
            RidgeProblem::new(ds.partition_seeded(4, 3), 0.05),
            topo.clone(),
            AlgorithmKind::Dsba,
        )
        .telemetry(TelemetrySpec::to_path("unused.jsonl"))
        .build();
        let e = exp.try_run().unwrap_err();
        assert!(e.contains("parallel"), "{e}");
        // a kill fault comes back as a named Err from try_run, not a panic
        let mut exp = Experiment::builder(
            RidgeProblem::new(ds.partition_seeded(4, 3), 0.05),
            topo,
            AlgorithmKind::Dsba,
        )
        .passes(4.0)
        .engine_kind(EngineKind::Parallel, 2)
        .fault(FaultSpec::parse("kill:1@2").unwrap())
        .build();
        let e = exp.try_run().unwrap_err();
        assert!(e.contains("killed by fault injection"), "{e}");
        assert!(e.contains("node 1") && e.contains("round 2"), "{e}");
    }

    #[test]
    fn rounds_for_target_respects_method_type() {
        let ds = SyntheticSpec::tiny().generate(62);
        let part = ds.partition_seeded(2, 3);
        let q = part.q;
        let topo = Topology::complete(2);
        let exp = Experiment::builder(RidgeProblem::new(part, 0.05), topo.clone(), AlgorithmKind::Dsba)
            .passes(3.0)
            .build();
        assert_eq!(exp.rounds_for_target(), 3 * q);
        let ds2 = SyntheticSpec::tiny().generate(62);
        let exp2 = Experiment::builder(
            RidgeProblem::new(ds2.partition_seeded(2, 3), 0.05),
            topo,
            AlgorithmKind::Extra,
        )
        .passes(3.0)
        .build();
        assert_eq!(exp2.rounds_for_target(), 3);
    }
}
