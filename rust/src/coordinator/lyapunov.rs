//! Lyapunov-function probe for Theorem 6.1.
//!
//! `H^t = ||X^t - X*||_M^2 + c D^t` with `X^t = [Z^t; U Q^t]`,
//! `Q^t = sum_{k<=t} U Z^k`, `M = diag(W~, I)`, `c = q / (96 L^2)`, and
//! `D^t` the table-vs-optimum discrepancy (41).  Theorem 6.1 proves
//! `E[H^{t+1}] <= (1 - min{gamma/12, mu/48L, 1/3q, 1/4}) E[H^t]` for
//! `alpha <= 1/(24 L)`; the `theorem61` bench measures the empirical
//! per-step contraction against that bound.
//!
//! The probe is operator-generic (its terms read only `coefs`,
//! `full_operator` and the row norms), so it applies verbatim to saddle
//! registry entries — the theorem's monotone-operator statement is what
//! lets DSBA keep the same geometric-rate verification story on minimax
//! workloads (cf. DSA, arXiv:1506.04216, for the gradient special
//! case).

use crate::algorithms::{Algorithm, Dsba};
use crate::graph::MixingMatrix;
use crate::linalg::{sqrt_psd, DenseMatrix};
use crate::operators::Problem;
use std::sync::Arc;

pub struct LyapunovProbe {
    problem: Arc<dyn Problem>,
    mix: MixingMatrix,
    /// U = ((I - W)/2)^{1/2}
    u: DenseMatrix,
    /// running Q^t = sum U Z^k  (N x dim)
    q_acc: DenseMatrix,
    /// U Q^* from the optimality conditions (15)
    uq_star: DenseMatrix,
    z_star: Vec<f64>,
    /// coefs of B_{n,i}(z*) for the D^t term
    star_coefs: Vec<Vec<f64>>,
    /// row norm factors for coef-space distance
    c_theorem: f64,
    alpha: f64,
}

impl LyapunovProbe {
    pub fn new(
        problem: Arc<dyn Problem>,
        mix: &MixingMatrix,
        z_star: Vec<f64>,
        alpha: f64,
    ) -> LyapunovProbe {
        let n = problem.nodes();
        let dim = problem.dim();
        // U^2 = Wt - W = (I - W)/2
        let mut u2 = DenseMatrix::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                u2[(i, j)] = mix.wt[(i, j)] - mix.w[(i, j)];
            }
        }
        let u = sqrt_psd(&u2, 1e-13);
        // U Q* solves U (U Q*) = -alpha B(Z*) restricted to range(U):
        // (U Q*) = -alpha U^+ B(Z*)
        let mut b_star = DenseMatrix::zeros(n, dim);
        let mut g = vec![0.0; dim];
        for nd in 0..n {
            problem.full_operator(nd, &z_star, &mut g);
            b_star.row_mut(nd).copy_from_slice(&g);
        }
        let uq_star = pinv_apply(&u, &b_star, -alpha);
        // SAGA-table coefficients at the optimum
        let w = problem.coef_width();
        let mut star_coefs = Vec::with_capacity(n);
        for nd in 0..n {
            let mut c = vec![0.0; problem.q() * w];
            for i in 0..problem.q() {
                problem.coefs(nd, i, &z_star, &mut c[i * w..(i + 1) * w]);
            }
            star_coefs.push(c);
        }
        let (l, _) = problem.l_mu();
        let c_theorem = problem.q() as f64 / (96.0 * l * l);
        LyapunovProbe {
            q_acc: DenseMatrix::zeros(n, dim),
            u,
            uq_star,
            z_star,
            star_coefs,
            c_theorem,
            alpha,
            problem,
            mix: mix.clone(),
        }
    }

    /// The theorem's contraction-rate bound
    /// `min{gamma/12, mu/48L, 1/(3q), 1/4}`.
    pub fn theoretical_rate(&self) -> f64 {
        let (l, mu) = self.problem.l_mu();
        (self.mix.gamma / 12.0)
            .min(mu / (48.0 * l))
            .min(1.0 / (3.0 * self.problem.q() as f64))
            .min(0.25)
    }

    /// Max step size the theorem allows: 1/(24 L).
    pub fn max_alpha(&self) -> f64 {
        let (l, _) = self.problem.l_mu();
        1.0 / (24.0 * l)
    }

    /// Fold the *current* iterates into Q and return H^t. Call once per
    /// DSBA round, after `step()`.
    pub fn observe(&mut self, alg: &Dsba) -> f64 {
        let p = self.problem.as_ref();
        let n = p.nodes();
        let dim = p.dim();
        let zs = alg.iterates();
        // Q^t += U Z^t
        for i in 0..n {
            for j in 0..n {
                let uij = self.u[(i, j)];
                if uij.abs() < 1e-300 {
                    continue;
                }
                let row = &zs[j];
                let qrow = self.q_acc.row_mut(i);
                for k in 0..dim {
                    qrow[k] += uij * row[k];
                }
            }
        }
        // ||Z - Z*||_{Wt}^2
        let mut dz = DenseMatrix::zeros(n, dim);
        for i in 0..n {
            for k in 0..dim {
                dz[(i, k)] = zs[i][k] - self.z_star[k];
            }
        }
        let z_term = dz.weighted_frob_sq(&self.mix.wt);
        // ||U Q - U Q*||^2
        let mut uq = DenseMatrix::zeros(n, dim);
        for i in 0..n {
            for j in 0..n {
                let uij = self.u[(i, j)];
                if uij.abs() < 1e-300 {
                    continue;
                }
                for k in 0..dim {
                    uq[(i, k)] += uij * self.q_acc[(j, k)];
                }
            }
        }
        let q_term: f64 = uq
            .data
            .iter()
            .zip(&self.uq_star.data)
            .map(|(a, b)| (a - b) * (a - b))
            .sum();
        // D^t from the SAGA tables (coef-space distance x row norms)
        let w = p.coef_width();
        let mut d_term = 0.0;
        for nd in 0..n {
            let saga = alg.saga(nd);
            let shard = &p.partition().shards[nd];
            for i in 0..p.q() {
                let cur = saga.coef(i);
                let star = &self.star_coefs[nd][i * w..(i + 1) * w];
                let dc0 = cur[0] - star[0];
                let mut val = dc0 * dc0 * shard.row_norm_sq(i);
                for k in 1..w {
                    let d = cur[k] - star[k];
                    val += d * d;
                }
                d_term += 2.0 * val / p.q() as f64;
            }
        }
        z_term + q_term + self.c_theorem * d_term
    }

    pub fn alpha(&self) -> f64 {
        self.alpha
    }
}

/// `scale * U^+ B` via eigen-decomposition of U (symmetric PSD).
fn pinv_apply(u: &DenseMatrix, b: &DenseMatrix, scale: f64) -> DenseMatrix {
    let n = u.rows;
    let (eig, v) = crate::linalg::symmetric_eigen(u, 1e-13);
    // U^+ = V diag(1/e if e > tol else 0) V^T
    let mut out = DenseMatrix::zeros(n, b.cols);
    // tmp = V^T B
    let vt = v.transpose();
    let tmp = vt.matmul(b);
    let mut scaled = tmp;
    for (k, &e) in eig.iter().enumerate() {
        let f = if e > 1e-9 { scale / e } else { 0.0 };
        for c in 0..scaled.cols {
            scaled[(k, c)] *= f;
        }
    }
    let res = v.matmul(&scaled);
    for i in 0..n {
        for c in 0..b.cols {
            out[(i, c)] = res[(i, c)];
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::AlgoParams;
    use crate::comm::{CommCostModel, Network};
    use crate::coordinator::solve_optimum;
    use crate::data::SyntheticSpec;
    use crate::graph::Topology;
    use crate::operators::RidgeProblem;

    #[test]
    fn lyapunov_decreases_geometrically_on_average() {
        let ds = SyntheticSpec::tiny().with_regression(true).generate(81);
        let part = ds.partition_seeded(4, 3);
        let topo = Topology::erdos_renyi(4, 0.6, 5);
        let mix = MixingMatrix::laplacian(&topo, 1.0);
        let p: Arc<dyn Problem> = Arc::new(RidgeProblem::new(part, 0.1));
        let z_star = solve_optimum(p.as_ref(), 1e-12);
        let mut probe = LyapunovProbe::new(p.clone(), &mix, z_star, 0.0);
        let alpha = probe.max_alpha(); // theorem's step size
        let params = AlgoParams::new(alpha, p.dim(), 7);
        let mut alg = crate::algorithms::Dsba::new(p.clone(), mix, topo.clone(), &params);
        let mut net = Network::new(topo, CommCostModel::default());
        let mut h = Vec::new();
        for _ in 0..40 * p.q() {
            alg.step(&mut net);
            h.push(probe.observe(&alg));
        }
        // geometric decrease over the run (allow stochastic wiggle):
        // the average contraction over the whole run must be at least as
        // fast as the theorem's rate
        let t = h.len() as f64;
        let measured = (h.last().unwrap() / h[0]).powf(1.0 / t);
        let bound = 1.0 - probe.theoretical_rate();
        assert!(
            measured <= bound + 1e-6,
            "measured contraction {measured} vs bound {bound}"
        );
        assert!(h.last().unwrap() < &(h[0] * 1e-3));
    }

    #[test]
    fn lyapunov_decreases_on_a_saddle_workload() {
        // the probe applied to a minimax registry entry: H^t must decay
        // under DSBA at the theorem's step size (well-conditioned
        // instance so the run length stays CI-sized)
        use crate::operators::RobustLsProblem;
        let ds = SyntheticSpec::tiny().with_regression(true).generate(83);
        let part = ds.partition_seeded(4, 3);
        let topo = Topology::erdos_renyi(4, 0.6, 5);
        let mix = MixingMatrix::laplacian(&topo, 1.0);
        let p: Arc<dyn Problem> = Arc::new(RobustLsProblem::new(part, 1.0, 2.0));
        let z_star = solve_optimum(p.as_ref(), 1e-12);
        let mut probe = LyapunovProbe::new(p.clone(), &mix, z_star, 0.0);
        let alpha = probe.max_alpha();
        let params = AlgoParams::new(alpha, p.dim(), 7);
        let mut alg = crate::algorithms::Dsba::new(p.clone(), mix, topo.clone(), &params);
        let mut net = Network::new(topo, CommCostModel::default());
        let mut h = Vec::new();
        for _ in 0..60 * p.q() {
            alg.step(&mut net);
            h.push(probe.observe(&alg));
        }
        assert!(h.iter().all(|v| v.is_finite()));
        assert!(
            h.last().unwrap() < &(h[0] * 0.5),
            "H did not decay: {} -> {}",
            h[0],
            h.last().unwrap()
        );
    }
}
