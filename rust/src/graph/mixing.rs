//! Mixing matrices `W` and their spectral quantities.
//!
//! Paper §4 requires: (i) graph sparsity, (ii) symmetry, (iii)
//! `null(I - W) = span{1}`, (iv) `0 <= W <= I`.  §7 builds the
//! Laplacian-based constant-edge-weight matrix `W = I - L/tau` with
//! `tau >= lambda_max(L)/2`; we default to `tau = lambda_max(L)/2 * margin`
//! with a small margin so that (iv) holds strictly.

use crate::graph::Topology;
use crate::linalg::{power_iteration, symmetric_eigenvalues, DenseMatrix};

/// A mixing matrix with its derived spectral data.
#[derive(Clone, Debug)]
pub struct MixingMatrix {
    /// `W` (satisfies (i)-(iv))
    pub w: DenseMatrix,
    /// `Wt = (I + W) / 2`
    pub wt: DenseMatrix,
    /// smallest nonzero eigenvalue of `U^2 = (I - W)/2` — the paper's gamma
    pub gamma: f64,
    /// graph condition number `kappa_g = 1/gamma`
    pub kappa_g: f64,
}

impl MixingMatrix {
    /// Laplacian-based constant edge weight matrix (paper §7):
    /// `W = I - L/tau` with `tau = margin * lambda_max(L)`, `margin >= 1`.
    ///
    /// Note: §7 states `tau >= lambda_max(L)/2`, but that only guarantees
    /// `-I <= W`; the spectral property (iv) of §4 (`0 <= W <= I`) that
    /// the analysis relies on needs `tau >= lambda_max(L)`, which is what
    /// we enforce (the looser scaling also empirically destabilizes the
    /// t=0 step that uses `W` rather than `W~`).
    pub fn laplacian(topo: &Topology, margin: f64) -> MixingMatrix {
        assert!(margin >= 1.0, "tau must satisfy tau >= lambda_max(L)");
        let n = topo.n;
        let mut lap = DenseMatrix::zeros(n, n);
        for i in 0..n {
            lap[(i, i)] = topo.degree(i) as f64;
            for &j in topo.neighbors(i) {
                lap[(i, j)] = -1.0;
            }
        }
        // power iteration overestimates tolerance-wise; pad slightly so the
        // spectral property (iv) is strict.
        let lmax = power_iteration(&lap, 300).max(1e-12) * 1.000001;
        let tau = margin * lmax;
        let mut w = DenseMatrix::identity(n);
        for i in 0..n {
            for j in 0..n {
                w[(i, j)] -= lap[(i, j)] / tau;
            }
        }
        Self::from_w(w)
    }

    /// Lazy Metropolis–Hastings weights: `w_ij = 1/(2(1 + max(d_i,
    /// d_j)))`, diagonal absorbs the remainder. The 1/2 laziness keeps
    /// the spectrum in [0, 1] (plain Metropolis admits negative
    /// eigenvalues, violating (iv)). Alternative construction used in the
    /// ablation benches.
    pub fn metropolis(topo: &Topology) -> MixingMatrix {
        let n = topo.n;
        let mut w = DenseMatrix::zeros(n, n);
        for i in 0..n {
            for &j in topo.neighbors(i) {
                w[(i, j)] =
                    0.5 / (1.0 + topo.degree(i).max(topo.degree(j)) as f64);
            }
            let off: f64 = (0..n).filter(|&j| j != i).map(|j| w[(i, j)]).sum();
            w[(i, i)] = 1.0 - off;
        }
        Self::from_w(w)
    }

    /// Wrap an explicit `W`, computing `Wt` and the spectral data.
    pub fn from_w(w: DenseMatrix) -> MixingMatrix {
        let n = w.rows;
        let mut wt = DenseMatrix::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                wt[(i, j)] = 0.5 * (w[(i, j)] + if i == j { 1.0 } else { 0.0 });
            }
        }
        // U^2 = Wt - W = (I - W)/2 ; gamma = smallest nonzero eigenvalue
        let mut u2 = DenseMatrix::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                u2[(i, j)] = wt[(i, j)] - w[(i, j)];
            }
        }
        let eig = symmetric_eigenvalues(&u2, 1e-13);
        let gamma = eig
            .iter()
            .copied()
            .find(|&e| e > 1e-9)
            .unwrap_or(1.0);
        MixingMatrix { w, wt, gamma, kappa_g: 1.0 / gamma }
    }

    pub fn n(&self) -> usize {
        self.w.rows
    }

    /// Verify conditions (i)-(iv) of §4; returns a violation description.
    pub fn check_conditions(&self, topo: &Topology, tol: f64) -> Result<(), String> {
        let n = self.n();
        for i in 0..n {
            for j in 0..n {
                // (i) graph sparsity
                if i != j
                    && !topo.neighbors(i).contains(&j)
                    && self.w[(i, j)].abs() > tol
                {
                    return Err(format!("(i) w[{i},{j}] nonzero off-graph"));
                }
                // (ii) symmetry
                if (self.w[(i, j)] - self.w[(j, i)]).abs() > tol {
                    return Err(format!("(ii) asymmetric at ({i},{j})"));
                }
            }
        }
        // (iii): W 1 = 1 (row sums) and 1 is the only null direction of I-W
        for i in 0..n {
            let s: f64 = (0..n).map(|j| self.w[(i, j)]).sum();
            if (s - 1.0).abs() > 1e-8 {
                return Err(format!("(iii) row {i} sums to {s}"));
            }
        }
        let mut iw = DenseMatrix::identity(n);
        for i in 0..n {
            for j in 0..n {
                iw[(i, j)] -= self.w[(i, j)];
            }
        }
        let eig = symmetric_eigenvalues(&iw, 1e-13);
        let zero_count = eig.iter().filter(|&&e| e.abs() < 1e-7).count();
        if zero_count != 1 {
            return Err(format!("(iii) null(I-W) has dim {zero_count}"));
        }
        // (iv): 0 <= spectrum(W) <= 1
        let ew = symmetric_eigenvalues(&self.w, 1e-13);
        if ew.first().copied().unwrap_or(0.0) < -1e-7
            || ew.last().copied().unwrap_or(0.0) > 1.0 + 1e-7
        {
            return Err(format!("(iv) spectrum out of [0,1]: {ew:?}"));
        }
        Ok(())
    }

    /// Local mixing: `out = sum_m wt[n][m] * (2 z[m] - zprev[m])` computed
    /// over neighbors only (O(deg * d)).
    pub fn mix_row(
        &self,
        node: usize,
        topo: &Topology,
        z: &[Vec<f64>],
        z_prev: &[Vec<f64>],
        out: &mut [f64],
    ) {
        out.fill(0.0);
        let touch = |m: usize, out: &mut [f64]| {
            let w = self.wt[(node, m)];
            if w == 0.0 {
                return;
            }
            let (zm, zpm) = (&z[m], &z_prev[m]);
            for k in 0..out.len() {
                out[k] += w * (2.0 * zm[k] - zpm[k]);
            }
        };
        touch(node, out);
        for &m in topo.neighbors(node) {
            touch(m, out);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn laplacian_mixing_satisfies_conditions() {
        let topo = Topology::erdos_renyi(10, 0.4, 42);
        let mix = MixingMatrix::laplacian(&topo, 1.0);
        mix.check_conditions(&topo, 1e-9).unwrap();
        assert!(mix.gamma > 0.0 && mix.gamma < 1.0);
        assert!(mix.kappa_g >= 1.0);
    }

    #[test]
    fn metropolis_mixing_satisfies_conditions() {
        let topo = Topology::ring(8);
        let mix = MixingMatrix::metropolis(&topo);
        mix.check_conditions(&topo, 1e-9).unwrap();
    }

    #[test]
    fn complete_graph_better_conditioned_than_ring() {
        let ring = MixingMatrix::laplacian(&Topology::ring(12), 1.0);
        let complete = MixingMatrix::laplacian(&Topology::complete(12), 1.0);
        assert!(
            complete.kappa_g < ring.kappa_g,
            "complete {} vs ring {}",
            complete.kappa_g,
            ring.kappa_g
        );
    }

    #[test]
    fn wt_is_half_identity_plus_half_w() {
        let topo = Topology::star(5);
        let mix = MixingMatrix::laplacian(&topo, 1.2);
        for i in 0..5 {
            for j in 0..5 {
                let want =
                    0.5 * (mix.w[(i, j)] + if i == j { 1.0 } else { 0.0 });
                assert!((mix.wt[(i, j)] - want).abs() < 1e-15);
            }
        }
    }

    #[test]
    fn mix_row_matches_dense() {
        let topo = Topology::erdos_renyi(6, 0.5, 9);
        let mix = MixingMatrix::laplacian(&topo, 1.0);
        let d = 4;
        let mut rng = crate::util::rng::Rng::new(1);
        let z: Vec<Vec<f64>> =
            (0..6).map(|_| (0..d).map(|_| rng.normal()).collect()).collect();
        let zp: Vec<Vec<f64>> =
            (0..6).map(|_| (0..d).map(|_| rng.normal()).collect()).collect();
        for node in 0..6 {
            let mut out = vec![0.0; d];
            mix.mix_row(node, &topo, &z, &zp, &mut out);
            // dense reference
            for k in 0..d {
                let mut want = 0.0;
                for m in 0..6 {
                    want += mix.wt[(node, m)] * (2.0 * z[m][k] - zp[m][k]);
                }
                assert!((out[k] - want).abs() < 1e-12);
            }
        }
    }
}
