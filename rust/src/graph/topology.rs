//! Graph topologies: generation, connectivity, BFS distances, diameter,
//! and the designated-parent forwarding trees of the §5.1 relay protocol.

use crate::util::rng::Rng;

/// Named topology families used across the benchmarks.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TopologyKind {
    /// Erdős–Rényi G(N, p) conditioned on connectivity (paper §7: p=0.4).
    ErdosRenyi,
    Ring,
    Path,
    Star,
    Complete,
    /// sqrt(N) x sqrt(N) 4-neighbor torus-free grid.
    Grid2d,
    /// Random k-regular-ish graph (k-nearest ring + random chords).
    SmallWorld,
}

impl TopologyKind {
    pub fn parse(s: &str) -> Option<TopologyKind> {
        Some(match s {
            "erdos-renyi" | "er" => TopologyKind::ErdosRenyi,
            "ring" => TopologyKind::Ring,
            "path" => TopologyKind::Path,
            "star" => TopologyKind::Star,
            "complete" => TopologyKind::Complete,
            "grid" | "grid2d" => TopologyKind::Grid2d,
            "small-world" => TopologyKind::SmallWorld,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            TopologyKind::ErdosRenyi => "erdos-renyi",
            TopologyKind::Ring => "ring",
            TopologyKind::Path => "path",
            TopologyKind::Star => "star",
            TopologyKind::Complete => "complete",
            TopologyKind::Grid2d => "grid",
            TopologyKind::SmallWorld => "small-world",
        }
    }
}

/// An undirected connected graph with adjacency lists and all-pairs BFS
/// distances.
#[derive(Clone, Debug)]
pub struct Topology {
    pub n: usize,
    /// sorted adjacency lists
    pub adj: Vec<Vec<usize>>,
    /// all-pairs hop distances `dist[i][j]`
    pub dist: Vec<Vec<usize>>,
    /// graph diameter `E = max_{i,j} dist(i,j)`
    pub diameter: usize,
}

impl Topology {
    /// Build from an edge list (deduplicated, self-loops ignored).
    pub fn from_edges(n: usize, edges: &[(usize, usize)]) -> Topology {
        let mut adj = vec![Vec::new(); n];
        for &(a, b) in edges {
            assert!(a < n && b < n, "edge ({a},{b}) out of range");
            if a == b {
                continue;
            }
            if !adj[a].contains(&b) {
                adj[a].push(b);
                adj[b].push(a);
            }
        }
        for l in &mut adj {
            l.sort_unstable();
        }
        let dist = all_pairs_bfs(&adj);
        let diameter = dist
            .iter()
            .flat_map(|row| row.iter().copied())
            .filter(|&d| d != usize::MAX)
            .max()
            .unwrap_or(0);
        Topology { n, adj, dist, diameter }
    }

    /// Erdős–Rényi G(n, p), resampled until connected (paper §7 setup:
    /// N=10, p=0.4).
    pub fn erdos_renyi(n: usize, p: f64, seed: u64) -> Topology {
        let mut rng = Rng::new(seed);
        for _attempt in 0..10_000 {
            let mut edges = Vec::new();
            for a in 0..n {
                for b in a + 1..n {
                    if rng.bernoulli(p) {
                        edges.push((a, b));
                    }
                }
            }
            let t = Topology::from_edges(n, &edges);
            if t.is_connected() {
                return t;
            }
        }
        panic!("could not sample a connected G({n},{p}) in 10000 attempts");
    }

    pub fn ring(n: usize) -> Topology {
        let edges: Vec<_> = (0..n).map(|i| (i, (i + 1) % n)).collect();
        Topology::from_edges(n, &edges)
    }

    pub fn path(n: usize) -> Topology {
        let edges: Vec<_> = (0..n.saturating_sub(1)).map(|i| (i, i + 1)).collect();
        Topology::from_edges(n, &edges)
    }

    pub fn star(n: usize) -> Topology {
        let edges: Vec<_> = (1..n).map(|i| (0, i)).collect();
        Topology::from_edges(n, &edges)
    }

    pub fn complete(n: usize) -> Topology {
        let mut edges = Vec::new();
        for a in 0..n {
            for b in a + 1..n {
                edges.push((a, b));
            }
        }
        Topology::from_edges(n, &edges)
    }

    /// Near-square 2-D grid covering n nodes.
    pub fn grid2d(n: usize) -> Topology {
        let w = (n as f64).sqrt().ceil() as usize;
        let mut edges = Vec::new();
        for i in 0..n {
            let (r, c) = (i / w, i % w);
            if c + 1 < w && i + 1 < n {
                edges.push((i, i + 1));
            }
            if (r + 1) * w + c < n {
                edges.push((i, (r + 1) * w + c));
            }
        }
        Topology::from_edges(n, &edges)
    }

    /// Ring + `chords` random chords (Watts–Strogatz-ish).
    pub fn small_world(n: usize, chords: usize, seed: u64) -> Topology {
        // on n < 4 every pair of distinct nodes is ring-adjacent, so no
        // chord can ever be sampled — fail fast instead of looping forever
        assert!(
            chords == 0 || n >= 4,
            "small_world({n}, {chords}): no non-ring chord exists below 4 nodes"
        );
        let mut rng = Rng::new(seed);
        let mut edges: Vec<_> = (0..n).map(|i| (i, (i + 1) % n)).collect();
        let mut added = 0;
        while added < chords {
            let a = rng.below(n);
            let b = rng.below(n);
            if a != b && (a + 1) % n != b && (b + 1) % n != a {
                edges.push((a.min(b), a.max(b)));
                added += 1;
            }
        }
        Topology::from_edges(n, &edges)
    }

    pub fn generate(kind: TopologyKind, n: usize, p: f64, seed: u64) -> Topology {
        match kind {
            TopologyKind::ErdosRenyi => Topology::erdos_renyi(n, p, seed),
            TopologyKind::Ring => Topology::ring(n),
            TopologyKind::Path => Topology::path(n),
            TopologyKind::Star => Topology::star(n),
            TopologyKind::Complete => Topology::complete(n),
            TopologyKind::Grid2d => Topology::grid2d(n),
            TopologyKind::SmallWorld => Topology::small_world(n, n / 2, seed),
        }
    }

    pub fn is_connected(&self) -> bool {
        self.n == 0 || self.dist[0].iter().all(|&d| d != usize::MAX)
    }

    pub fn neighbors(&self, i: usize) -> &[usize] {
        &self.adj[i]
    }

    pub fn degree(&self, i: usize) -> usize {
        self.adj[i].len()
    }

    /// Max degree `Delta(G)` (Table 1 communication cost).
    pub fn max_degree(&self) -> usize {
        (0..self.n).map(|i| self.degree(i)).max().unwrap_or(0)
    }

    pub fn edge_count(&self) -> usize {
        self.adj.iter().map(|l| l.len()).sum::<usize>() / 2
    }

    /// Distance groups of §5.1 relative to `root`:
    /// `V_j = { n : dist(root, n) = j }`, j = 0..=diameter.
    pub fn distance_groups(&self, root: usize) -> Vec<Vec<usize>> {
        let mut groups = vec![Vec::new(); self.diameter + 1];
        for m in 0..self.n {
            let d = self.dist[root][m];
            if d != usize::MAX {
                groups[d].push(m);
            }
        }
        groups
    }

    /// Structural hash of the graph (FNV-1a over the node count and the
    /// sorted adjacency lists). Exchanged in the TCP transport handshake
    /// so two endpoints refuse to pair engines built over different
    /// topologies.
    pub fn fingerprint(&self) -> u64 {
        const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
        const PRIME: u64 = 0x0000_0100_0000_01b3;
        fn mix(h: u64, v: u64) -> u64 {
            (h ^ v).wrapping_mul(PRIME)
        }
        let mut h = mix(OFFSET, self.n as u64);
        for (i, l) in self.adj.iter().enumerate() {
            h = mix(h, i as u64);
            h = mix(h, l.len() as u64);
            for &m in l {
                h = mix(h, m as u64);
            }
        }
        h
    }

    /// Designated parent of `node` on the BFS forwarding tree rooted at
    /// `src`: the *minimum-index* neighbor one hop closer to `src`
    /// (paper §5.1: "only the one with the minimum node index sends").
    /// `None` when `node == src`.
    pub fn designated_parent(&self, src: usize, node: usize) -> Option<usize> {
        if node == src {
            return None;
        }
        let d = self.dist[src][node];
        self.adj[node]
            .iter()
            .copied()
            .filter(|&m| self.dist[src][m] + 1 == d)
            .min()
    }
}

fn all_pairs_bfs(adj: &[Vec<usize>]) -> Vec<Vec<usize>> {
    let n = adj.len();
    let mut dist = vec![vec![usize::MAX; n]; n];
    let mut queue = std::collections::VecDeque::new();
    for s in 0..n {
        dist[s][s] = 0;
        queue.clear();
        queue.push_back(s);
        while let Some(u) = queue.pop_front() {
            for &v in &adj[u] {
                if dist[s][v] == usize::MAX {
                    dist[s][v] = dist[s][u] + 1;
                    queue.push_back(v);
                }
            }
        }
    }
    dist
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_properties() {
        let t = Topology::ring(6);
        assert!(t.is_connected());
        assert_eq!(t.diameter, 3);
        assert_eq!(t.max_degree(), 2);
        assert_eq!(t.edge_count(), 6);
        assert_eq!(t.dist[0][3], 3);
    }

    #[test]
    fn star_properties() {
        let t = Topology::star(7);
        assert_eq!(t.diameter, 2);
        assert_eq!(t.max_degree(), 6);
        assert_eq!(t.degree(3), 1);
    }

    #[test]
    fn complete_diameter_one() {
        let t = Topology::complete(5);
        assert_eq!(t.diameter, 1);
        assert_eq!(t.edge_count(), 10);
    }

    #[test]
    fn er_connected_and_seeded() {
        let a = Topology::erdos_renyi(10, 0.4, 42);
        let b = Topology::erdos_renyi(10, 0.4, 42);
        assert!(a.is_connected());
        assert_eq!(a.adj, b.adj, "same seed, same graph");
    }

    #[test]
    fn grid_connected() {
        for n in [4, 9, 10, 16, 23] {
            assert!(Topology::grid2d(n).is_connected(), "grid {n}");
        }
    }

    #[test]
    fn distance_groups_partition_nodes() {
        let t = Topology::erdos_renyi(12, 0.3, 7);
        let groups = t.distance_groups(0);
        let total: usize = groups.iter().map(|g| g.len()).sum();
        assert_eq!(total, 12);
        assert_eq!(groups[0], vec![0]);
        for (j, g) in groups.iter().enumerate() {
            for &m in g {
                assert_eq!(t.dist[0][m], j);
            }
        }
    }

    #[test]
    fn designated_parent_is_closer_and_min() {
        let t = Topology::erdos_renyi(10, 0.4, 3);
        for src in 0..t.n {
            for node in 0..t.n {
                if node == src {
                    assert!(t.designated_parent(src, node).is_none());
                    continue;
                }
                let p = t.designated_parent(src, node).unwrap();
                assert_eq!(t.dist[src][p] + 1, t.dist[src][node]);
                // minimality
                for &m in t.neighbors(node) {
                    if t.dist[src][m] + 1 == t.dist[src][node] {
                        assert!(p <= m);
                    }
                }
            }
        }
    }

    #[test]
    fn path_diameter() {
        let t = Topology::path(5);
        assert_eq!(t.diameter, 4);
    }

    #[test]
    fn fingerprint_separates_topologies() {
        // deterministic across constructions of the same graph
        assert_eq!(
            Topology::erdos_renyi(10, 0.4, 42).fingerprint(),
            Topology::erdos_renyi(10, 0.4, 42).fingerprint()
        );
        // different structure, node count, or labeling hashes apart
        let ring = Topology::ring(6).fingerprint();
        assert_ne!(ring, Topology::ring(7).fingerprint());
        assert_ne!(ring, Topology::path(6).fingerprint());
        assert_ne!(ring, Topology::star(6).fingerprint());
    }
}
