//! Network graphs, mixing matrices, and their spectral properties.
//!
//! The decentralized setting of the paper: `N` nodes on a connected
//! undirected graph `G`, a doubly-stochastic-like mixing matrix
//! `W = I - L/tau` built from the Laplacian (§7), and the derived spectral
//! quantities: `gamma` (smallest nonzero eigenvalue of `(I - W)/2`), the
//! graph condition number `kappa_g = 1/gamma`, diameter `E`, and the
//! distance groups `V_j` used by the sparse-communication relay (§5.1).

mod topology;
mod mixing;

pub use mixing::MixingMatrix;
pub use topology::{Topology, TopologyKind};
