//! Pluggable wire compression with CHOCO-style error feedback.
//!
//! DSBA-s (PAPER.md §5.1) shows the wire layer can carry *exact* sparse
//! deltas; this module generalizes the idea to **lossy** schemes from the
//! compressed-gossip literature — top-k / random-k sparsification and
//! QSGD-style stochastic quantization — behind one [`Compressor`] trait.
//! Each lossy stream is wrapped in per-edge [`ErrorFeedback`] memory
//! (`x_hat += Q(x - x_hat)`, the CHOCO-SGD estimate-tracking scheme), so
//! the quantization error of round `t` is re-sent at round `t + 1`
//! instead of being lost, and dense-gossip methods (DGD / EXTRA / DSA /
//! dense DSBA) keep converging under compression.
//!
//! The sender compresses the *difference* between its iterate and the
//! shared estimate `x_hat`; every receiver holds a bit-identical replica
//! of `x_hat` (both sides apply the same quantized delta, which travels
//! verbatim on the wire as a `COMP` frame, f64 bits intact), so no RNG or
//! compressor state is needed on the receive side. [`Identity`] is the
//! exact member of the family: it ships the full vector and *assigns*
//! `x_hat = x` on both ends, which is what makes the Identity parity pin
//! bit-for-bit (an accumulate of `x - x_hat` would drift in the last ulp).
//!
//! Selection is a [`CompressionSpec`] (`none | identity | topk:K |
//! randk:K | qsgd:L`) carried by [`crate::runtime::EngineSpec`]; `none`
//! bypasses the machinery entirely. The sequential driver is always the
//! uncompressed reference — compression applies to the parallel engine's
//! transport boundary only.

use crate::util::rng::Rng;

/// A compressed vector as it travels on the wire: explicit support
/// (`idx`, strictly increasing) with the quantized values, plus the
/// *declared* honest wire size of the scheme's real binary encoding
/// ([`Compressor::bytes_on_wire`]) so both endpoints account identical
/// byte totals regardless of the in-memory representation.
#[derive(Clone, Debug, PartialEq)]
pub struct CompressedVec {
    /// dimension of the (dense) vector this compresses
    pub dim: usize,
    /// support, strictly increasing, every entry `< dim`
    pub idx: Vec<u32>,
    /// quantized values, one per support entry
    pub val: Vec<f64>,
    /// declared bytes-on-wire of the scheme's binary encoding
    pub bytes: u64,
}

impl CompressedVec {
    pub fn nnz(&self) -> usize {
        self.idx.len()
    }

    /// Scatter to a dense vector (zeros off-support).
    pub fn to_dense(&self) -> Vec<f64> {
        let mut out = vec![0.0; self.dim];
        for (&i, &v) in self.idx.iter().zip(&self.val) {
            out[i as usize] = v;
        }
        out
    }
}

/// A (possibly lossy, possibly randomized) vector compression operator.
///
/// `compress` takes `&mut self` because the randomized members
/// ([`RandomK`], [`Qsgd`]) advance a deterministic per-node RNG stream —
/// which is what keeps lossy runs reproducible at any thread count.
pub trait Compressor: Send {
    /// Quantize `x` into its wire form.
    fn compress(&mut self, x: &[f64]) -> CompressedVec;

    /// Reconstruct the dense approximation the receiver sees.
    fn decompress(&self, c: &CompressedVec) -> Vec<f64> {
        c.to_dense()
    }

    /// Declared honest wire bytes for one dim-`dim` payload.
    fn bytes_on_wire(&self, dim: usize) -> u64;

    /// Contraction factor `c` with `||x - Q(x)||^2 <= c * ||x||^2`
    /// (deterministic for [`TopK`] / [`Qsgd`], in expectation for
    /// [`RandomK`]; `0` for [`Identity`]). Error feedback converges when
    /// `c < 1` — the property suite pins `x_hat -> x` at this rate.
    fn contraction(&self, dim: usize) -> f64;

    /// Exact compressors ship the full vector bit-for-bit; error
    /// feedback then *assigns* `x_hat = x` instead of accumulating.
    fn is_exact(&self) -> bool {
        false
    }

    fn name(&self) -> String;
}

/// Exact pass-through: full support, original f64 bits, `8 d` bytes.
pub struct Identity;

impl Compressor for Identity {
    fn compress(&mut self, x: &[f64]) -> CompressedVec {
        CompressedVec {
            dim: x.len(),
            idx: (0..x.len() as u32).collect(),
            val: x.to_vec(),
            bytes: self.bytes_on_wire(x.len()),
        }
    }

    fn bytes_on_wire(&self, dim: usize) -> u64 {
        8 * dim as u64
    }

    fn contraction(&self, _dim: usize) -> f64 {
        0.0
    }

    fn is_exact(&self) -> bool {
        true
    }

    fn name(&self) -> String {
        "identity".to_string()
    }
}

/// Keep the `k` largest-magnitude coordinates (deterministic; ties break
/// toward the lower index). `12 k` bytes (u32 index + f64 value each).
pub struct TopK {
    pub k: usize,
}

impl Compressor for TopK {
    fn compress(&mut self, x: &[f64]) -> CompressedVec {
        let k = self.k.min(x.len());
        let mut order: Vec<u32> = (0..x.len() as u32).collect();
        order.sort_by(|&a, &b| {
            let (ma, mb) = (x[a as usize].abs(), x[b as usize].abs());
            mb.partial_cmp(&ma)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.cmp(&b))
        });
        let mut idx = order[..k].to_vec();
        idx.sort_unstable();
        let val = idx.iter().map(|&i| x[i as usize]).collect();
        CompressedVec { dim: x.len(), idx, val, bytes: self.bytes_on_wire(x.len()) }
    }

    fn bytes_on_wire(&self, dim: usize) -> u64 {
        12 * self.k.min(dim) as u64
    }

    fn contraction(&self, dim: usize) -> f64 {
        if dim == 0 {
            return 0.0;
        }
        1.0 - self.k.min(dim) as f64 / dim as f64
    }

    fn name(&self) -> String {
        format!("topk:{}", self.k)
    }
}

/// Keep `k` uniformly random coordinates (kept values exact, like top-k,
/// so a coordinate is reconstructed perfectly the round it is drawn).
pub struct RandomK {
    pub k: usize,
    rng: Rng,
}

impl RandomK {
    pub fn new(k: usize, seed: u64) -> RandomK {
        RandomK { k, rng: Rng::new(seed) }
    }
}

impl Compressor for RandomK {
    fn compress(&mut self, x: &[f64]) -> CompressedVec {
        let k = self.k.min(x.len());
        let mut idx: Vec<u32> = self
            .rng
            .sample_indices(x.len(), k)
            .into_iter()
            .map(|i| i as u32)
            .collect();
        idx.sort_unstable();
        let val = idx.iter().map(|&i| x[i as usize]).collect();
        CompressedVec { dim: x.len(), idx, val, bytes: self.bytes_on_wire(x.len()) }
    }

    fn bytes_on_wire(&self, dim: usize) -> u64 {
        12 * self.k.min(dim) as u64
    }

    fn contraction(&self, dim: usize) -> f64 {
        if dim == 0 {
            return 0.0;
        }
        1.0 - self.k.min(dim) as f64 / dim as f64
    }

    fn name(&self) -> String {
        format!("randk:{}", self.k)
    }
}

/// QSGD-style stochastic quantization with `levels` uniform levels per
/// sign: each coordinate is dithered to `sign(x_i) * ||x||_2 * l / s`
/// with `l` the stochastic rounding of `|x_i| / ||x||_2 * s`. Wire cost
/// is the scheme's real encoding — the f64 norm plus
/// `ceil(log2(2s + 1))` bits per coordinate (level + sign, dense
/// bitmap), independent of how many levels round to zero.
pub struct Qsgd {
    pub levels: u32,
    rng: Rng,
}

impl Qsgd {
    pub fn new(levels: u32, seed: u64) -> Qsgd {
        assert!(levels >= 1, "qsgd needs at least one level");
        Qsgd { levels, rng: Rng::new(seed) }
    }

    fn bits_per_coord(&self) -> u64 {
        // ceil(log2(2s + 1)) distinct states: levels 0..=s, two signs
        let states = 2 * self.levels as u64 + 1;
        (64 - (states - 1).leading_zeros()) as u64
    }
}

impl Compressor for Qsgd {
    fn compress(&mut self, x: &[f64]) -> CompressedVec {
        let s = self.levels as f64;
        let norm = x.iter().map(|v| v * v).sum::<f64>().sqrt();
        let mut idx = Vec::new();
        let mut val = Vec::new();
        if norm > 0.0 && norm.is_finite() {
            for (i, &v) in x.iter().enumerate() {
                let u = v.abs() / norm * s;
                let base = u.floor();
                let up = self.rng.uniform() < u - base;
                let lvl = if up { base + 1.0 } else { base };
                if lvl > 0.0 {
                    idx.push(i as u32);
                    val.push(v.signum() * norm * lvl / s);
                }
            }
        }
        CompressedVec { dim: x.len(), idx, val, bytes: self.bytes_on_wire(x.len()) }
    }

    fn bytes_on_wire(&self, dim: usize) -> u64 {
        8 + (dim as u64 * self.bits_per_coord() + 7) / 8
    }

    /// Per-realization bound: dithering moves each coordinate by at most
    /// `||x|| / s`, so `||x - Q(x)||^2 <= (d / s^2) ||x||^2`. Only a
    /// contraction when `s > sqrt(d)` — pick levels accordingly.
    fn contraction(&self, dim: usize) -> f64 {
        dim as f64 / (self.levels as f64 * self.levels as f64)
    }

    fn name(&self) -> String {
        format!("qsgd:{}", self.levels)
    }
}

/// Per-edge CHOCO error-feedback state. `x_hat` is the shared estimate
/// both endpoints of a directed edge track; `memory` is the residual
/// `x - x_hat` left after the round's quantized delta was absorbed — the
/// error that the *next* compression round gets to re-send.
#[derive(Clone, Debug)]
pub struct ErrorFeedback {
    pub x_hat: Vec<f64>,
    pub memory: Vec<f64>,
}

impl ErrorFeedback {
    pub fn new(dim: usize) -> ErrorFeedback {
        ErrorFeedback { x_hat: vec![0.0; dim], memory: vec![0.0; dim] }
    }

    /// Sender side: quantize `x - x_hat`, absorb the delta into `x_hat`
    /// (assign for exact compressors), refresh `memory`, and return the
    /// wire payload.
    pub fn encode(&mut self, comp: &mut dyn Compressor, x: &[f64]) -> CompressedVec {
        assert_eq!(
            x.len(),
            self.x_hat.len(),
            "error-feedback dim {} but payload dim {}",
            self.x_hat.len(),
            x.len()
        );
        let c = if comp.is_exact() {
            let c = comp.compress(x);
            self.apply(&c, true);
            c
        } else {
            let delta: Vec<f64> =
                x.iter().zip(&self.x_hat).map(|(a, b)| a - b).collect();
            let c = comp.compress(&delta);
            self.apply(&c, false);
            c
        };
        for (m, (a, b)) in self.memory.iter_mut().zip(x.iter().zip(&self.x_hat)) {
            *m = a - b;
        }
        c
    }

    /// Receiver side (and the shared half of [`ErrorFeedback::encode`]):
    /// absorb a wire delta into `x_hat`. Both endpoints run exactly this
    /// arithmetic on exactly these bits, so the replicas stay
    /// bit-identical without any back-channel.
    pub fn apply(&mut self, c: &CompressedVec, exact: bool) {
        assert_eq!(
            c.dim,
            self.x_hat.len(),
            "error-feedback dim {} but COMP frame dim {}",
            self.x_hat.len(),
            c.dim
        );
        if exact {
            self.x_hat.fill(0.0);
            for (&i, &v) in c.idx.iter().zip(&c.val) {
                self.x_hat[i as usize] = v;
            }
        } else {
            for (&i, &v) in c.idx.iter().zip(&c.val) {
                self.x_hat[i as usize] += v;
            }
        }
    }
}

/// Which compression runs on the parallel engine's transport boundary.
/// `None` bypasses the machinery entirely (`--compress none`, the
/// default, bit-for-bit identical to the uncompressed engine).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum CompressionSpec {
    None,
    Identity,
    TopK(usize),
    RandK(usize),
    Qsgd(u32),
}

impl Default for CompressionSpec {
    fn default() -> CompressionSpec {
        CompressionSpec::None
    }
}

impl CompressionSpec {
    /// Parse `none | identity | topk:K | randk:K | qsgd:L` (K, L >= 1).
    pub fn parse(s: &str) -> Result<CompressionSpec, String> {
        let s = s.trim().to_ascii_lowercase();
        if s == "none" {
            return Ok(CompressionSpec::None);
        }
        if s == "identity" {
            return Ok(CompressionSpec::Identity);
        }
        let bad = || {
            format!(
                "bad compression spec '{s}' \
                 (expected none | identity | topk:K | randk:K | qsgd:L)"
            )
        };
        let (head, arg) = s.split_once(':').ok_or_else(bad)?;
        match head {
            "topk" | "randk" => {
                let k: usize = arg.parse().map_err(|_| bad())?;
                if k == 0 {
                    return Err(format!("compression spec '{s}': K must be >= 1"));
                }
                Ok(if head == "topk" {
                    CompressionSpec::TopK(k)
                } else {
                    CompressionSpec::RandK(k)
                })
            }
            "qsgd" => {
                let l: u32 = arg.parse().map_err(|_| bad())?;
                if l == 0 {
                    return Err(format!("compression spec '{s}': L must be >= 1"));
                }
                Ok(CompressionSpec::Qsgd(l))
            }
            _ => Err(bad()),
        }
    }

    /// Canonical spec string (`parse(name())` is the identity).
    pub fn name(&self) -> String {
        match self {
            CompressionSpec::None => "none".to_string(),
            CompressionSpec::Identity => "identity".to_string(),
            CompressionSpec::TopK(k) => format!("topk:{k}"),
            CompressionSpec::RandK(k) => format!("randk:{k}"),
            CompressionSpec::Qsgd(l) => format!("qsgd:{l}"),
        }
    }

    /// Whether the compressed stream carries the exact vector (error
    /// feedback assigns instead of accumulating).
    pub fn is_exact(&self) -> bool {
        matches!(self, CompressionSpec::None | CompressionSpec::Identity)
    }

    /// Instantiate the per-node compressor (`None` for the bypass). The
    /// RNG stream is derived from the experiment seed and the node index,
    /// so split engine processes agree without communicating.
    pub fn build_for_node(&self, seed: u64, node: usize) -> Option<Box<dyn Compressor>> {
        let node_seed = seed ^ (node as u64 + 1).wrapping_mul(0xA076_1D64_78BD_642F);
        match *self {
            CompressionSpec::None => None,
            CompressionSpec::Identity => Some(Box::new(Identity)),
            CompressionSpec::TopK(k) => Some(Box::new(TopK { k })),
            CompressionSpec::RandK(k) => Some(Box::new(RandomK::new(k, node_seed))),
            CompressionSpec::Qsgd(l) => Some(Box::new(Qsgd::new(l, node_seed))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn l2sq(x: &[f64]) -> f64 {
        x.iter().map(|v| v * v).sum()
    }

    #[test]
    fn spec_parse_and_name_roundtrip() {
        for s in ["none", "identity", "topk:4", "randk:8", "qsgd:16"] {
            let spec = CompressionSpec::parse(s).unwrap();
            assert_eq!(spec.name(), s);
        }
        assert_eq!(CompressionSpec::parse(" TopK:3 ").unwrap(), CompressionSpec::TopK(3));
        for bad in ["", "topk", "topk:", "topk:0", "topk:-1", "qsgd:0", "gzip:9"] {
            assert!(CompressionSpec::parse(bad).is_err(), "accepted '{bad}'");
        }
    }

    #[test]
    fn identity_is_bit_exact() {
        let x = vec![0.1, -0.0, 3.5e-300, f64::MAX, -2.0];
        let mut c = Identity;
        let q = c.compress(&x);
        assert_eq!(q.nnz(), x.len());
        let back = c.decompress(&q);
        for (a, b) in x.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(q.bytes, 8 * x.len() as u64);
    }

    #[test]
    fn topk_keeps_largest_and_contracts() {
        let x = vec![1.0, -5.0, 0.5, 4.0, -0.25, 3.0];
        let mut c = TopK { k: 3 };
        let q = c.compress(&x);
        assert_eq!(q.idx, vec![1, 3, 5]);
        assert_eq!(q.val, vec![-5.0, 4.0, 3.0]);
        assert_eq!(q.bytes, 36);
        let err: Vec<f64> = x
            .iter()
            .zip(&c.decompress(&q))
            .map(|(a, b)| a - b)
            .collect();
        assert!(l2sq(&err) <= c.contraction(x.len()) * l2sq(&x) + 1e-12);
    }

    #[test]
    fn topk_truncates_k_to_dim() {
        let x = vec![2.0, -1.0];
        let mut c = TopK { k: 10 };
        let q = c.compress(&x);
        assert_eq!(q.idx, vec![0, 1]);
        assert_eq!(q.bytes, 24);
    }

    #[test]
    fn randk_support_is_valid_and_deterministic() {
        let x: Vec<f64> = (0..40).map(|i| (i as f64).sin()).collect();
        let mut a = RandomK::new(7, 99);
        let mut b = RandomK::new(7, 99);
        for _ in 0..20 {
            let qa = a.compress(&x);
            let qb = b.compress(&x);
            assert_eq!(qa, qb, "same seed must give the same stream");
            assert_eq!(qa.nnz(), 7);
            assert!(qa.idx.windows(2).all(|w| w[0] < w[1]));
            assert!(qa.idx.iter().all(|&i| (i as usize) < x.len()));
            for (&i, &v) in qa.idx.iter().zip(&qa.val) {
                assert_eq!(v, x[i as usize]);
            }
        }
    }

    #[test]
    fn qsgd_error_within_declared_contraction() {
        let x: Vec<f64> = (0..32).map(|i| ((i * 37 % 13) as f64 - 6.0) / 3.0).collect();
        let mut c = Qsgd::new(64, 5);
        for _ in 0..10 {
            let q = c.compress(&x);
            let err: Vec<f64> = x
                .iter()
                .zip(&c.decompress(&q))
                .map(|(a, b)| a - b)
                .collect();
            assert!(
                l2sq(&err) <= c.contraction(x.len()) * l2sq(&x) + 1e-12,
                "dithering moved a coordinate more than ||x||/s"
            );
        }
    }

    #[test]
    fn qsgd_declared_bytes_match_bit_width() {
        // 2*64 + 1 = 129 states -> 8 bits per coordinate
        let c = Qsgd::new(64, 0);
        assert_eq!(c.bytes_on_wire(100), 8 + 100);
        // 2*1 + 1 = 3 states -> 2 bits per coordinate
        let c = Qsgd::new(1, 0);
        assert_eq!(c.bytes_on_wire(8), 8 + 2);
    }

    #[test]
    fn qsgd_zero_vector_compresses_empty() {
        let mut c = Qsgd::new(4, 1);
        let q = c.compress(&[0.0; 6]);
        assert_eq!(q.nnz(), 0);
        assert_eq!(c.decompress(&q), vec![0.0; 6]);
    }

    #[test]
    fn error_feedback_identity_assigns_exactly() {
        let x = vec![0.3, -1.7, 2.25];
        let mut ef = ErrorFeedback::new(3);
        let mut c = Identity;
        // seed x_hat away from zero so accumulate-vs-assign would differ
        ef.x_hat = vec![0.1, 0.1, 0.1];
        let q = ef.encode(&mut c, &x);
        for (a, b) in ef.x_hat.iter().zip(&x) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert!(ef.memory.iter().all(|&m| m == 0.0));
        // the receiver replica lands on the same bits
        let mut rx = ErrorFeedback::new(3);
        rx.apply(&q, true);
        assert_eq!(rx.x_hat, ef.x_hat);
    }

    #[test]
    fn error_feedback_topk_converges_to_constant_target() {
        let x: Vec<f64> = (0..12).map(|i| (i as f64 - 5.5) / 4.0).collect();
        let mut ef = ErrorFeedback::new(12);
        let mut rx = ErrorFeedback::new(12);
        let mut c = TopK { k: 4 };
        // from x_hat = 0 every selected coordinate lands exactly on x_i,
        // so ceil(d/k) rounds empty the residual completely
        for _ in 0..4 {
            let q = ef.encode(&mut c, &x);
            rx.apply(&q, false);
        }
        assert_eq!(ef.x_hat, x);
        assert_eq!(rx.x_hat, x, "receiver replica must track the sender");
        assert!(l2sq(&ef.memory) == 0.0);
    }

    #[test]
    fn error_feedback_memory_is_the_residual() {
        let x = vec![4.0, 1.0, -3.0, 0.5];
        let mut ef = ErrorFeedback::new(4);
        let mut c = TopK { k: 2 };
        ef.encode(&mut c, &x);
        // top-2 absorbed {4.0, -3.0}; the memory holds what was dropped
        assert_eq!(ef.memory, vec![0.0, 1.0, 0.0, 0.5]);
    }

    #[test]
    fn build_for_node_streams_are_node_dependent() {
        let spec = CompressionSpec::RandK(3);
        let x: Vec<f64> = (0..30).map(|i| i as f64 + 1.0).collect();
        let stream = |node: usize| -> Vec<u32> {
            let mut c = spec.build_for_node(7, node).unwrap();
            (0..5).flat_map(|_| c.compress(&x).idx).collect()
        };
        assert_eq!(stream(0), stream(0), "node stream must be reproducible");
        assert_ne!(stream(0), stream(1), "distinct nodes should draw distinct supports");
        assert!(CompressionSpec::None.build_for_node(7, 0).is_none());
    }
}
