//! Communication substrate: an in-process network with per-node DOUBLE
//! accounting (the paper's `C_n^t` / `C_max^t` metric, §7), the
//! sparse-delta relay protocol of §5.1, and the typed [`Message`] payloads
//! the per-node runtime moves across edges.
//!
//! The simulator is synchronous-round-based, matching the paper's model:
//! all messages sent in round `t` are available to their receivers at the
//! start of round `t+1` (neighbor-to-neighbor hops only).

pub mod compressor;
mod message;
mod network;
mod relay;

pub use compressor::{
    CompressedVec, CompressionSpec, Compressor, ErrorFeedback, Identity, Qsgd, RandomK,
    TopK,
};
pub use message::{Message, Nack, Outgoing, Watermark, WatermarkKind};
// the bounded wire reader is shared with the metrics STATS-payload codec
// so every frame family gets the same corrupt-frame hardening
pub(crate) use message::Reader;
pub use network::{CommCostModel, Network};
pub use relay::{RelayDelta, RelayProtocol};
