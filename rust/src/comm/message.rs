//! Typed messages crossing topology edges, with cost accounting and a
//! lossless wire codec.
//!
//! The per-node runtime (sequential driver and parallel engine alike)
//! moves three payload families: dense iterate broadcasts (the
//! EXTRA / DSA / dense-DSBA / DLM / SSDA / DGD exchange), the §5.1
//! sparse relay deltas of DSBA-s, and `COMP` error-feedback deltas when
//! the engine runs a lossy [`crate::comm::Compressor`] on the dense
//! broadcast. Costs are priced through
//! [`CommCostModel`] identically to the legacy `round_dense_exchange` /
//! `RelayProtocol::round` accounting, so engine traffic is comparable
//! DOUBLE-for-DOUBLE with the paper's `C_n^t` metric.
//!
//! The codec is an explicit little-endian layout (f64 via `to_bits`, so
//! round-tripping is bit-exact); `rust/tests/properties.rs` pins
//! encode → decode as the identity. Alongside the payload families the
//! module defines the [`Watermark`] control frame — the versioned
//! end-of-round progress announcement both engine clocks synchronize on.

use crate::comm::{CompressedVec, Network, RelayDelta};
use crate::linalg::SparseVec;
use std::sync::Arc;

/// One typed payload on an edge of the topology.
///
/// The dense variant is reference-counted so a broadcast allocates and
/// copies the iterate **once** per round, not once per edge — clones
/// handed to each neighbor (and sent across engine threads) share the
/// payload. Receivers keep the `Arc` itself (see
/// `algorithms::node::NeighborBuf`), so delivery is pointer rotation.
#[derive(Clone, Debug, PartialEq)]
pub enum Message {
    /// Dense vector broadcast (an iterate `z_m^t`, `d` DOUBLEs).
    Dense(Arc<Vec<f64>>),
    /// Sparse §5.1 relay delta (support of one data row + dense tail).
    Sparse(RelayDelta),
    /// Compressed iterate delta (`comm::compressor` error-feedback
    /// stream); `Arc`-shared like the dense broadcast it replaces, so a
    /// node compresses and encodes once per round, not once per edge.
    Comp(Arc<CompressedVec>),
}

/// A message addressed to one neighbor.
#[derive(Clone, Debug)]
pub struct Outgoing {
    pub to: usize,
    pub msg: Message,
}

const TAG_DENSE: u8 = 0;
const TAG_SPARSE: u8 = 1;
const TAG_COMP: u8 = 2;

/// Codec version of the [`Watermark`] control frame (bumped independently
/// of the transport's handshake `WIRE_VERSION`).
const WATERMARK_VERSION: u8 = 1;
const WM_KIND_ROUND: u8 = 0;
const WM_KIND_STATS: u8 = 1;

/// End-of-round control record: node `node` has finished emitting round
/// `round`. This single versioned frame subsumes the two legacy control
/// frames — the bare END marker (`kind = RoundComplete`) and the
/// split-run STATS flood (`kind = Stats`, which rides the same
/// progress-announcement channel with a payload).
///
/// Watermarks are what the engine's clocks synchronize on: the sync
/// `RoundClock` consumes them as end-of-round markers, and the async
/// `AsyncClock` admits a node into round `t` once every in-neighbor's
/// watermark covers `t - tau` (see `runtime::engine`).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Watermark {
    /// Emitting node (global topology index).
    pub node: u32,
    /// Round the node has emitted through.
    pub round: u64,
    pub kind: WatermarkKind,
}

/// What a [`Watermark`] announces.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum WatermarkKind {
    /// All of the node's round-`round` messages precede this frame.
    RoundComplete,
    /// Split-run stats flood: hop `hop` of the per-node stat rows
    /// gathered at sample point `round` (see `metrics::encode_stat_rows`).
    Stats { hop: u32, payload: Vec<u8> },
}

impl Watermark {
    /// Serialize to the wire layout:
    /// `version u8 | node u32 | round u64 | kind u8 [| hop u32 | len u64 | payload]`.
    pub fn encode(&self) -> Vec<u8> {
        let mut out = vec![WATERMARK_VERSION];
        put_u32(&mut out, self.node);
        put_u64(&mut out, self.round);
        match &self.kind {
            WatermarkKind::RoundComplete => out.push(WM_KIND_ROUND),
            WatermarkKind::Stats { hop, payload } => {
                out.push(WM_KIND_STATS);
                put_u32(&mut out, *hop);
                put_u64(&mut out, payload.len() as u64);
                out.extend_from_slice(payload);
            }
        }
        out
    }

    /// Bit-exact inverse of [`Watermark::encode`]. Total on arbitrary
    /// bytes (the stats payload length is bounded by the remaining buffer
    /// before any allocation), trailing bytes are rejected, and accepted
    /// frames are canonical: `decode(b)?.encode() == b`.
    pub fn decode(buf: &[u8]) -> Result<Watermark, String> {
        let mut r = Reader::new(buf);
        let version = r.u8()?;
        if version != WATERMARK_VERSION {
            return Err(format!(
                "unsupported watermark version {version} (expected {WATERMARK_VERSION})"
            ));
        }
        let node = r.u32()?;
        let round = r.u64()?;
        let kind = match r.u8()? {
            WM_KIND_ROUND => WatermarkKind::RoundComplete,
            WM_KIND_STATS => {
                let hop = r.u32()?;
                let len = r.count("stats payload len", 1)?;
                let payload = r.take(len)?.to_vec();
                WatermarkKind::Stats { hop, payload }
            }
            other => return Err(format!("unknown watermark kind {other}")),
        };
        if r.pos != buf.len() {
            return Err(format!("{} trailing bytes after watermark", buf.len() - r.pos));
        }
        Ok(Watermark { node, round, kind })
    }
}

/// Link-layer negative acknowledgement: the receiver on one edge saw a
/// gap in the sender's link sequence numbers and asks for the half-open
/// range `[from_seq, to_seq)` to be retransmitted (see the reliable-link
/// protocol in `runtime::transport`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Nack {
    /// First missing link sequence number.
    pub from_seq: u64,
    /// One past the last missing link sequence number.
    pub to_seq: u64,
}

impl Nack {
    /// Serialize to the 16-byte wire layout `from_seq u64 | to_seq u64`.
    pub fn encode(&self) -> [u8; 16] {
        let mut b = [0u8; 16];
        b[0..8].copy_from_slice(&self.from_seq.to_le_bytes());
        b[8..16].copy_from_slice(&self.to_seq.to_le_bytes());
        b
    }

    /// Bit-exact inverse of [`Nack::encode`]: exactly 16 bytes and a
    /// non-empty range, so accepted frames are canonical.
    pub fn decode(buf: &[u8]) -> Result<Nack, String> {
        if buf.len() != 16 {
            return Err(format!("nack frame is {} bytes (want 16)", buf.len()));
        }
        let from_seq = u64::from_le_bytes(buf[0..8].try_into().unwrap());
        let to_seq = u64::from_le_bytes(buf[8..16].try_into().unwrap());
        if from_seq >= to_seq {
            return Err(format!("empty nack range [{from_seq}, {to_seq})"));
        }
        Ok(Nack { from_seq, to_seq })
    }
}

impl Message {
    /// Wrap an owned vector as a dense payload.
    pub fn dense(v: Vec<f64>) -> Message {
        Message::Dense(Arc::new(v))
    }

    /// Account this message on edge (from, to) at the network's cost
    /// model — dense length or sparse (nnz, tail) pricing.
    pub fn charge(&self, net: &mut Network, from: usize, to: usize) {
        match self {
            Message::Dense(v) => net.send_dense(from, to, v.len()),
            Message::Sparse(d) => net.send_sparse(from, to, d.vec.nnz(), d.tail.len()),
            Message::Comp(c) => net.send_comp(from, to, c.nnz(), c.bytes),
        }
    }

    /// Payload size in DOUBLE-equivalents under `cost` (header included).
    pub fn cost(&self, cost: &crate::comm::CommCostModel) -> f64 {
        match self {
            Message::Dense(v) => cost.dense_cost(v.len()),
            Message::Sparse(d) => cost.sparse_cost(d.vec.nnz(), d.tail.len()),
            Message::Comp(c) => cost.comp_cost(c.nnz()),
        }
    }

    /// Serialize to the wire layout.
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        match self {
            Message::Dense(v) => {
                out.push(TAG_DENSE);
                put_u64(&mut out, v.len() as u64);
                for &x in v {
                    put_f64(&mut out, x);
                }
            }
            Message::Sparse(d) => {
                out.push(TAG_SPARSE);
                put_u32(&mut out, d.src);
                put_u32(&mut out, d.t);
                put_u64(&mut out, d.vec.dim as u64);
                put_u64(&mut out, d.vec.nnz() as u64);
                for &i in &d.vec.idx {
                    put_u32(&mut out, i);
                }
                for &v in &d.vec.val {
                    put_f64(&mut out, v);
                }
                put_u64(&mut out, d.tail.len() as u64);
                for &v in &d.tail {
                    put_f64(&mut out, v);
                }
            }
            Message::Comp(c) => {
                out.push(TAG_COMP);
                put_u64(&mut out, c.dim as u64);
                put_u64(&mut out, c.nnz() as u64);
                for &i in &c.idx {
                    put_u32(&mut out, i);
                }
                for &v in &c.val {
                    put_f64(&mut out, v);
                }
                put_u64(&mut out, c.bytes);
            }
        }
        out
    }

    /// Reconstruct from the wire layout (bit-exact inverse of `encode`).
    ///
    /// Total on arbitrary bytes: every length field is bounded by the
    /// remaining buffer before any allocation (a flipped bit can never
    /// trigger a multi-GB `Vec::with_capacity`), and sparse frames are
    /// validated structurally (`nnz <= dim`, every `idx < dim`, indices
    /// strictly increasing) so a corrupt frame can never materialize an
    /// invalid [`SparseVec`]. Accepted frames are canonical:
    /// `decode(b)?.encode() == b`.
    pub fn decode(buf: &[u8]) -> Result<Message, String> {
        let mut r = Reader::new(buf);
        let tag = r.u8()?;
        let msg = match tag {
            TAG_DENSE => {
                let len = r.count("dense len", 8)?;
                let mut v = Vec::with_capacity(len);
                for _ in 0..len {
                    v.push(r.f64()?);
                }
                Message::Dense(Arc::new(v))
            }
            TAG_SPARSE => {
                let src = r.u32()?;
                let t = r.u32()?;
                let dim_raw = r.u64()?;
                let dim = usize::try_from(dim_raw)
                    .map_err(|_| format!("dim {dim_raw} exceeds address space"))?;
                // one entry = 4 idx bytes + 8 val bytes
                let nnz = r.count("sparse nnz", 12)?;
                if nnz > dim {
                    return Err(format!("nnz {nnz} exceeds dim {dim}"));
                }
                let mut idx = Vec::with_capacity(nnz);
                for _ in 0..nnz {
                    let i = r.u32()?;
                    if i as usize >= dim {
                        return Err(format!("idx {i} out of dim {dim}"));
                    }
                    if idx.last().is_some_and(|&prev| i <= prev) {
                        return Err(format!("idx {i} not strictly increasing"));
                    }
                    idx.push(i);
                }
                let mut val = Vec::with_capacity(nnz);
                for _ in 0..nnz {
                    val.push(r.f64()?);
                }
                let tail_len = r.count("tail len", 8)?;
                let mut tail = Vec::with_capacity(tail_len);
                for _ in 0..tail_len {
                    tail.push(r.f64()?);
                }
                Message::Sparse(RelayDelta { src, t, vec: SparseVec { dim, idx, val }, tail })
            }
            TAG_COMP => {
                let dim_raw = r.u64()?;
                let dim = usize::try_from(dim_raw)
                    .map_err(|_| format!("dim {dim_raw} exceeds address space"))?;
                // one support entry = 4 idx bytes + 8 val bytes
                let nnz = r.count("comp nnz", 12)?;
                if nnz > dim {
                    return Err(format!("nnz {nnz} exceeds dim {dim}"));
                }
                let mut idx = Vec::with_capacity(nnz);
                for _ in 0..nnz {
                    let i = r.u32()?;
                    if i as usize >= dim {
                        return Err(format!("idx {i} out of dim {dim}"));
                    }
                    if idx.last().is_some_and(|&prev| i <= prev) {
                        return Err(format!("idx {i} not strictly increasing"));
                    }
                    idx.push(i);
                }
                let mut val = Vec::with_capacity(nnz);
                for _ in 0..nnz {
                    val.push(r.f64()?);
                }
                let bytes = r.u64()?;
                Message::Comp(Arc::new(CompressedVec { dim, idx, val, bytes }))
            }
            other => return Err(format!("unknown message tag {other}")),
        };
        if r.pos != buf.len() {
            return Err(format!("{} trailing bytes after message", buf.len() - r.pos));
        }
        Ok(msg)
    }
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f64(out: &mut Vec<u8>, v: f64) {
    out.extend_from_slice(&v.to_bits().to_le_bytes());
}

/// Bounds-checked little-endian reader behind every wire codec in the
/// system ([`Message::decode`] here, the metrics layer's STATS-payload
/// codec): length fields are always validated against the remaining
/// buffer before any allocation.
pub(crate) struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    pub(crate) fn new(buf: &'a [u8]) -> Reader<'a> {
        Reader { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], String> {
        // checked: a huge `n` must fail cleanly, not wrap the bound below
        let end = self
            .pos
            .checked_add(n)
            .ok_or_else(|| "length overflow".to_string())?;
        if end > self.buf.len() {
            return Err("truncated message".to_string());
        }
        let s = &self.buf[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    pub(crate) fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// Read a u64 element-count field and bound it by the bytes actually
    /// left in the buffer (`elem_bytes` per element), so corrupt frames
    /// can never drive an over-allocation.
    pub(crate) fn count(&mut self, what: &str, elem_bytes: usize) -> Result<usize, String> {
        let raw = self.u64()?;
        let max = (self.remaining() / elem_bytes) as u64;
        if raw > max {
            return Err(format!(
                "{what} {raw} exceeds remaining buffer ({max} elements max)"
            ));
        }
        Ok(raw as usize)
    }

    fn u8(&mut self) -> Result<u8, String> {
        Ok(self.take(1)?[0])
    }

    pub(crate) fn u32(&mut self) -> Result<u32, String> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub(crate) fn u64(&mut self) -> Result<u64, String> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub(crate) fn f64(&mut self) -> Result<f64, String> {
        Ok(f64::from_bits(u64::from_le_bytes(self.take(8)?.try_into().unwrap())))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_roundtrip_bit_exact() {
        let m = Message::dense(vec![0.0, -0.0, 1.5, f64::MIN_POSITIVE, 1e300]);
        let back = Message::decode(&m.encode()).unwrap();
        // -0.0 == 0.0 under PartialEq, so compare bits explicitly too
        match (&m, &back) {
            (Message::Dense(a), Message::Dense(b)) => {
                assert_eq!(a.len(), b.len());
                for (x, y) in a.iter().zip(b.iter()) {
                    assert_eq!(x.to_bits(), y.to_bits());
                }
            }
            _ => panic!("tag changed"),
        }
    }

    #[test]
    fn sparse_roundtrip() {
        let m = Message::Sparse(RelayDelta {
            src: 3,
            t: 41,
            vec: SparseVec::from_pairs(100, vec![(4, -1.25), (99, 3.5)]),
            tail: vec![0.1, 0.2, 0.3],
        });
        assert_eq!(Message::decode(&m.encode()).unwrap(), m);
    }

    #[test]
    fn decode_rejects_garbage() {
        assert!(Message::decode(&[]).is_err());
        assert!(Message::decode(&[7]).is_err());
        let mut enc = Message::dense(vec![1.0]).encode();
        enc.push(0); // trailing byte
        assert!(Message::decode(&enc).is_err());
    }

    #[test]
    fn decode_bounds_length_fields_before_allocating() {
        // dense frame claiming u64::MAX doubles: must error, never allocate
        let mut b = vec![TAG_DENSE];
        put_u64(&mut b, u64::MAX);
        assert!(Message::decode(&b).is_err());
        // dense frame claiming more doubles than the buffer holds
        let mut b = Message::dense(vec![1.0, 2.0]).encode();
        b[1..9].copy_from_slice(&1000u64.to_le_bytes());
        assert!(Message::decode(&b).is_err());
        // sparse frame with a huge nnz field
        let mut b = vec![TAG_SPARSE];
        put_u32(&mut b, 0); // src
        put_u32(&mut b, 0); // t
        put_u64(&mut b, 10); // dim
        put_u64(&mut b, u64::MAX); // nnz
        assert!(Message::decode(&b).is_err());
        // sparse frame with a huge tail length
        let mut b = Message::Sparse(RelayDelta {
            src: 0,
            t: 0,
            vec: SparseVec::from_pairs(4, vec![(1, 1.0)]),
            tail: vec![],
        })
        .encode();
        let tail_field = b.len() - 8;
        b[tail_field..].copy_from_slice(&u64::MAX.to_le_bytes());
        assert!(Message::decode(&b).is_err());
    }

    #[test]
    fn decode_rejects_structurally_invalid_sparse() {
        fn sparse_frame(dim: u64, idx: &[u32], val: &[f64]) -> Vec<u8> {
            let mut b = vec![TAG_SPARSE];
            put_u32(&mut b, 3); // src
            put_u32(&mut b, 7); // t
            put_u64(&mut b, dim);
            put_u64(&mut b, idx.len() as u64);
            for &i in idx {
                put_u32(&mut b, i);
            }
            for &v in val {
                put_f64(&mut b, v);
            }
            put_u64(&mut b, 0); // tail
            b
        }
        // nnz > dim
        assert!(Message::decode(&sparse_frame(1, &[0, 1], &[1.0, 2.0])).is_err());
        // idx out of dim
        assert!(Message::decode(&sparse_frame(5, &[2, 5], &[1.0, 2.0])).is_err());
        // duplicate / unsorted idx
        assert!(Message::decode(&sparse_frame(5, &[2, 2], &[1.0, 2.0])).is_err());
        assert!(Message::decode(&sparse_frame(5, &[3, 1], &[1.0, 2.0])).is_err());
        // the well-formed variant still decodes
        assert!(Message::decode(&sparse_frame(5, &[1, 3], &[1.0, 2.0])).is_ok());
    }

    fn comp_msg() -> Message {
        Message::Comp(Arc::new(CompressedVec {
            dim: 20,
            idx: vec![0, 7, 19],
            val: vec![-0.5, 2.25, 1e-200],
            bytes: 36,
        }))
    }

    #[test]
    fn comp_roundtrip_bit_exact() {
        let m = comp_msg();
        let back = Message::decode(&m.encode()).unwrap();
        assert_eq!(back, m);
        // canonical: re-encoding an accepted frame reproduces the bytes
        assert_eq!(back.encode(), m.encode());
    }

    #[test]
    fn decode_rejects_structurally_invalid_comp() {
        fn comp_frame(dim: u64, idx: &[u32], val: &[f64], bytes: u64) -> Vec<u8> {
            let mut b = vec![TAG_COMP];
            put_u64(&mut b, dim);
            put_u64(&mut b, idx.len() as u64);
            for &i in idx {
                put_u32(&mut b, i);
            }
            for &v in val {
                put_f64(&mut b, v);
            }
            put_u64(&mut b, bytes);
            b
        }
        // nnz > dim
        assert!(Message::decode(&comp_frame(1, &[0, 1], &[1.0, 2.0], 24)).is_err());
        // idx out of dim
        assert!(Message::decode(&comp_frame(5, &[2, 5], &[1.0, 2.0], 24)).is_err());
        // duplicate / unsorted idx
        assert!(Message::decode(&comp_frame(5, &[2, 2], &[1.0, 2.0], 24)).is_err());
        assert!(Message::decode(&comp_frame(5, &[3, 1], &[1.0, 2.0], 24)).is_err());
        // huge nnz field must error before allocating
        let mut b = vec![TAG_COMP];
        put_u64(&mut b, 10);
        put_u64(&mut b, u64::MAX);
        assert!(Message::decode(&b).is_err());
        // the well-formed variant (empty support included) still decodes
        assert!(Message::decode(&comp_frame(5, &[1, 3], &[1.0, 2.0], 24)).is_ok());
        assert!(Message::decode(&comp_frame(5, &[], &[], 9)).is_ok());
    }

    #[test]
    fn decode_every_truncation_errs() {
        for msg in [
            Message::dense(vec![1.0, -2.5, 3.0]),
            Message::Sparse(RelayDelta {
                src: 1,
                t: 9,
                vec: SparseVec::from_pairs(16, vec![(2, 0.5), (7, -1.0)]),
                tail: vec![4.0],
            }),
            comp_msg(),
        ] {
            let enc = msg.encode();
            for k in 0..enc.len() {
                assert!(
                    Message::decode(&enc[..k]).is_err(),
                    "prefix {k}/{} decoded Ok",
                    enc.len()
                );
            }
        }
    }

    #[test]
    fn watermark_roundtrip_both_kinds() {
        let end = Watermark { node: 7, round: 42, kind: WatermarkKind::RoundComplete };
        assert_eq!(Watermark::decode(&end.encode()).unwrap(), end);
        let stats = Watermark {
            node: 3,
            round: u64::MAX,
            kind: WatermarkKind::Stats { hop: 2, payload: vec![0xAB, 0, 0xFF, 17] },
        };
        let enc = stats.encode();
        let back = Watermark::decode(&enc).unwrap();
        assert_eq!(back, stats);
        // canonical: re-encoding an accepted frame reproduces the bytes
        assert_eq!(back.encode(), enc);
    }

    #[test]
    fn watermark_decode_every_truncation_errs() {
        for wm in [
            Watermark { node: 1, round: 5, kind: WatermarkKind::RoundComplete },
            Watermark {
                node: 9,
                round: 0,
                kind: WatermarkKind::Stats { hop: 1, payload: vec![1, 2, 3] },
            },
        ] {
            let enc = wm.encode();
            for k in 0..enc.len() {
                assert!(
                    Watermark::decode(&enc[..k]).is_err(),
                    "prefix {k}/{} decoded Ok",
                    enc.len()
                );
            }
        }
    }

    #[test]
    fn watermark_decode_rejects_garbage() {
        // bad version
        let mut enc = Watermark { node: 0, round: 0, kind: WatermarkKind::RoundComplete }.encode();
        enc[0] = WATERMARK_VERSION + 1;
        assert!(Watermark::decode(&enc).is_err());
        // unknown kind tag
        let mut enc = Watermark { node: 0, round: 0, kind: WatermarkKind::RoundComplete }.encode();
        let kind_at = enc.len() - 1;
        enc[kind_at] = 9;
        assert!(Watermark::decode(&enc).is_err());
        // trailing byte
        let mut enc = Watermark { node: 0, round: 3, kind: WatermarkKind::RoundComplete }.encode();
        enc.push(0);
        assert!(Watermark::decode(&enc).is_err());
        // stats payload length exceeding the buffer must error, never allocate
        let mut enc = Watermark {
            node: 2,
            round: 1,
            kind: WatermarkKind::Stats { hop: 0, payload: vec![5; 4] },
        }
        .encode();
        let len_at = enc.len() - 4 - 8;
        enc[len_at..len_at + 8].copy_from_slice(&u64::MAX.to_le_bytes());
        assert!(Watermark::decode(&enc).is_err());
    }

    #[test]
    fn nack_roundtrip_and_rejections() {
        let n = Nack { from_seq: 3, to_seq: 9 };
        assert_eq!(Nack::decode(&n.encode()).unwrap(), n);
        let max = Nack { from_seq: 0, to_seq: u64::MAX };
        assert_eq!(Nack::decode(&max.encode()).unwrap(), max);
        // every truncation errs
        let enc = n.encode();
        for k in 0..enc.len() {
            assert!(Nack::decode(&enc[..k]).is_err(), "prefix {k} decoded Ok");
        }
        // trailing byte
        let mut long = enc.to_vec();
        long.push(0);
        assert!(Nack::decode(&long).is_err());
        // empty and inverted ranges are rejected
        assert!(Nack::decode(&Nack { from_seq: 5, to_seq: 5 }.encode()).is_err());
        assert!(Nack::decode(&Nack { from_seq: 9, to_seq: 2 }.encode()).is_err());
    }

    #[test]
    fn charge_matches_cost_model() {
        use crate::comm::{CommCostModel, Network};
        use crate::graph::Topology;
        let topo = Topology::ring(4);
        let cost = CommCostModel::default();
        let mut net = Network::new(topo, cost);
        let dense = Message::dense(vec![0.0; 10]);
        dense.charge(&mut net, 0, 1);
        assert_eq!(net.received_by(1), cost.dense_cost(10));
        let sparse = Message::Sparse(RelayDelta {
            src: 0,
            t: 0,
            vec: SparseVec::from_pairs(10, vec![(1, 1.0), (2, 2.0)]),
            tail: vec![9.0],
        });
        sparse.charge(&mut net, 1, 2);
        assert_eq!(net.received_by(2), cost.sparse_cost(2, 1));
        let comp = comp_msg();
        comp.charge(&mut net, 2, 3);
        assert_eq!(net.received_by(3), cost.comp_cost(3));
        assert_eq!(net.bytes_received_by(3), 36.0);
        assert_eq!(dense.cost(&cost), cost.dense_cost(10));
        assert_eq!(sparse.cost(&cost), cost.sparse_cost(2, 1));
        assert_eq!(comp.cost(&cost), cost.comp_cost(3));
    }
}
