//! The §5.1 sparse-delta relay forwarding trees.
//!
//! Every node's fresh delta `delta_n^t` must reach every other node `m`
//! after exactly `dist(n, m)` hops.  The paper organizes this by distance
//! groups (`V_j` sends `F_j^t = F_{j+1}^{t-1} ∪ {G_j^t}` to `V_{j-1}`,
//! deduplicated by minimum node index).  Equivalently — and this is how we
//! implement it — each source `n` induces a BFS forwarding tree in which a
//! node forwards a delta of source `s` only to the neighbors whose
//! *designated parent* (minimum-index closer neighbor) it is.  Each delta
//! then crosses every tree edge exactly once, so a node receives at most
//! `N - 1` deltas per round: the `O(N rho d)` DOUBLEs of Table 1.
//!
//! [`RelayProtocol`] holds the precomputed children tables; the actual
//! per-round forwarding (inject own fresh delta, forward last round's
//! receipts one hop) lives in the per-node DSBA-s implementation
//! (`crate::algorithms::DsbaSparse`), which consults
//! [`RelayProtocol::children`] each round. The unit tests below simulate
//! that exact schedule to pin the tree/timing invariants.

use crate::graph::Topology;
use crate::linalg::SparseVec;

/// A sparse update in flight: produced by `src` at iteration `t`.
#[derive(Clone, Debug, PartialEq)]
pub struct RelayDelta {
    pub src: u32,
    pub t: u32,
    /// sparse feature-block payload (support of one data row)
    pub vec: SparseVec,
    /// dense tail (AUC scalars), empty for pure minimization problems
    pub tail: Vec<f64>,
}

/// Precomputed BFS forwarding trees.
pub struct RelayProtocol {
    /// children[node][src] = neighbors to which `node` forwards deltas
    /// originating at `src`
    children: Vec<Vec<Vec<usize>>>,
}

impl RelayProtocol {
    pub fn new(topo: &Topology) -> RelayProtocol {
        let n = topo.n;
        let mut children = vec![vec![Vec::new(); n]; n];
        for node in 0..n {
            for src in 0..n {
                if src == node {
                    continue;
                }
                // node forwards src-deltas to neighbor l iff l is one hop
                // farther from src and node is l's designated parent
                for &l in topo.neighbors(node) {
                    if topo.dist[src][l] == topo.dist[src][node] + 1
                        && topo.designated_parent(src, l) == Some(node)
                    {
                        children[node][src].push(l);
                    }
                }
            }
            // a source sends its own fresh delta to ALL neighbors for
            // which it is the designated parent (distance-1 nodes: parent
            // is src itself, uniquely)
            let mut own = Vec::new();
            for &l in topo.neighbors(node) {
                if topo.designated_parent(node, l) == Some(node) {
                    own.push(l);
                }
            }
            children[node][node] = own;
        }
        RelayProtocol { children }
    }

    /// Forwarding targets of `node` for deltas originating at `src`.
    pub fn children(&self, node: usize, src: usize) -> &[usize] {
        &self.children[node][src]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{CommCostModel, Network};

    /// Simulate the per-node forwarding schedule (the same one
    /// `DsbaSparseNode::outgoing` runs): every node injects one fresh
    /// delta per round and forwards last round's receipts one hop along
    /// the children tables. Returns per-node inboxes per round.
    fn simulate(
        topo: &Topology,
        rounds: usize,
        net: &mut Network,
    ) -> Vec<Vec<Vec<RelayDelta>>> {
        let relay = RelayProtocol::new(topo);
        let n = topo.n;
        let mut pending: Vec<Vec<RelayDelta>> = vec![Vec::new(); n];
        let mut inboxes_per_round = Vec::with_capacity(rounds);
        for r in 0..rounds {
            let mut inbox: Vec<Vec<RelayDelta>> = vec![Vec::new(); n];
            for node in 0..n {
                let mut msgs = std::mem::take(&mut pending[node]);
                msgs.push(RelayDelta {
                    src: node as u32,
                    t: r as u32,
                    vec: SparseVec::from_pairs(8, vec![(1, 1.0)]),
                    tail: vec![],
                });
                for d in msgs {
                    for &l in relay.children(node, d.src as usize) {
                        net.send_sparse(node, l, d.vec.nnz(), d.tail.len());
                        inbox[l].push(d.clone());
                    }
                }
            }
            pending = inbox.clone();
            inboxes_per_round.push(inbox);
        }
        inboxes_per_round
    }

    fn run_protocol(topo: &Topology, rounds: usize) -> Vec<Vec<(u32, u32, usize)>> {
        // returns per-node log of (src, t, arrival_round)
        let mut net = Network::new(topo.clone(), CommCostModel::values_only());
        let per_round = simulate(topo, rounds, &mut net);
        let mut log: Vec<Vec<(u32, u32, usize)>> = vec![Vec::new(); topo.n];
        for (r, inbox) in per_round.into_iter().enumerate() {
            for (node, msgs) in inbox.into_iter().enumerate() {
                for d in msgs {
                    log[node].push((d.src, d.t, r));
                }
            }
        }
        log
    }

    #[test]
    fn every_delta_arrives_once_with_bfs_delay() {
        for topo in [
            Topology::erdos_renyi(10, 0.4, 42),
            Topology::ring(7),
            Topology::star(6),
            Topology::path(5),
        ] {
            let rounds = 12 + topo.diameter;
            let log = run_protocol(&topo, rounds);
            for node in 0..topo.n {
                use std::collections::HashMap;
                let mut seen: HashMap<(u32, u32), usize> = HashMap::new();
                for &(src, t, r) in &log[node] {
                    assert!(
                        seen.insert((src, t), r).is_none(),
                        "duplicate delivery of ({src},{t}) at node {node}"
                    );
                    // arrival round = t + dist(src, node) - 1 (a delta
                    // produced/injected in round t takes dist hops =>
                    // arrives in round t + dist - 1, 0-based)
                    let d = topo.dist[src as usize][node];
                    assert_eq!(
                        r,
                        t as usize + d - 1,
                        "wrong delay for ({src},{t}) -> {node}, dist {d}"
                    );
                }
                // completeness: all deltas old enough must have arrived
                for src in 0..topo.n {
                    if src == node {
                        continue;
                    }
                    let d = topo.dist[src][node];
                    for t in 0..rounds.saturating_sub(d) {
                        assert!(
                            seen.contains_key(&(src as u32, t as u32)),
                            "missing ({src},{t}) at node {node}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn per_round_inbox_bounded_by_n_minus_one() {
        let topo = Topology::erdos_renyi(12, 0.35, 5);
        let mut net = Network::new(topo.clone(), CommCostModel::values_only());
        let per_round = simulate(&topo, 30, &mut net);
        for inbox in &per_round {
            for msgs in inbox {
                assert!(msgs.len() <= topo.n - 1, "steady-state bound violated");
            }
        }
    }

    #[test]
    fn forwarding_trees_are_spanning() {
        let topo = Topology::erdos_renyi(9, 0.4, 11);
        let relay = RelayProtocol::new(&topo);
        for src in 0..topo.n {
            // count tree edges: every non-src node has exactly one parent
            let mut covered = vec![false; topo.n];
            covered[src] = true;
            let mut edges = 0;
            for node in 0..topo.n {
                for &child in relay.children(node, src) {
                    assert!(!covered[child], "node {child} has two parents");
                    covered[child] = true;
                    edges += 1;
                }
            }
            assert!(covered.iter().all(|&c| c), "tree of {src} not spanning");
            assert_eq!(edges, topo.n - 1);
        }
    }
}
