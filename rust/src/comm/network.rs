//! Byte/DOUBLE accounting for the simulated network.

use crate::graph::Topology;

/// How transmitted payloads are priced in DOUBLEs.
#[derive(Clone, Copy, Debug)]
pub struct CommCostModel {
    /// cost of one dense f64 value
    pub dense_double: f64,
    /// cost of one sparse (index, value) pair — the paper counts DOUBLEs,
    /// so an index is priced as one DOUBLE-equivalent by default (2.0 per
    /// nnz total); set to 1.0 to count values only.
    pub sparse_pair: f64,
    /// fixed per-message header cost
    pub header: f64,
}

impl Default for CommCostModel {
    fn default() -> Self {
        CommCostModel { dense_double: 1.0, sparse_pair: 2.0, header: 2.0 }
    }
}

impl CommCostModel {
    /// Count payload values only (the most charitable sparse accounting,
    /// matching the paper's O(rho d) statement).
    pub fn values_only() -> Self {
        CommCostModel { dense_double: 1.0, sparse_pair: 1.0, header: 0.0 }
    }

    pub fn dense_cost(&self, len: usize) -> f64 {
        self.header + self.dense_double * len as f64
    }

    pub fn sparse_cost(&self, nnz: usize, tail: usize) -> f64 {
        self.header + self.sparse_pair * nnz as f64 + self.dense_double * tail as f64
    }

    /// A compressed (`COMP`) payload in DOUBLE-equivalents: its support
    /// travels as (index, value) pairs, priced like the sparse relay.
    pub fn comp_cost(&self, nnz: usize) -> f64 {
        self.header + self.sparse_pair * nnz as f64
    }
}

/// Honest payload bytes of a dense vector on the wire (8 per f64).
pub fn dense_bytes(len: usize) -> f64 {
    8.0 * len as f64
}

/// Honest payload bytes of a sparse (idx u32, val f64) message + tail.
pub fn sparse_bytes(nnz: usize, tail: usize) -> f64 {
    12.0 * nnz as f64 + 8.0 * tail as f64
}

/// Per-node received-DOUBLE (and bytes-on-wire) counters over a topology.
#[derive(Clone, Debug)]
pub struct Network {
    pub topo: Topology,
    pub cost: CommCostModel,
    /// DOUBLEs received by each node so far (C_n^t)
    received: Vec<f64>,
    /// DOUBLEs sent by each node so far
    sent: Vec<f64>,
    /// bytes-on-wire received by each node (honest payload bytes: 8 per
    /// dense f64, 12 per sparse pair, a compressor's declared size for
    /// `COMP` frames — the cost-model knobs do not rescale these)
    received_bytes: Vec<f64>,
    /// bytes-on-wire sent by each node
    sent_bytes: Vec<f64>,
    /// messages delivered
    messages: u64,
}

impl Network {
    pub fn new(topo: Topology, cost: CommCostModel) -> Network {
        let n = topo.n;
        Network {
            topo,
            cost,
            received: vec![0.0; n],
            sent: vec![0.0; n],
            received_bytes: vec![0.0; n],
            sent_bytes: vec![0.0; n],
            messages: 0,
        }
    }

    fn assert_edge(&self, from: usize, to: usize) {
        debug_assert!(
            self.topo.neighbors(from).contains(&to),
            "({from},{to}) is not an edge — decentralized algorithms may \
             only use neighbor links"
        );
    }

    /// Account a dense vector of `len` doubles on edge (from, to).
    pub fn send_dense(&mut self, from: usize, to: usize, len: usize) {
        self.assert_edge(from, to);
        let c = self.cost.dense_cost(len);
        self.received[to] += c;
        self.sent[from] += c;
        self.received_bytes[to] += dense_bytes(len);
        self.sent_bytes[from] += dense_bytes(len);
        self.messages += 1;
    }

    /// Account a sparse vector (nnz index/value pairs + dense tail).
    pub fn send_sparse(&mut self, from: usize, to: usize, nnz: usize, tail: usize) {
        self.assert_edge(from, to);
        let c = self.cost.sparse_cost(nnz, tail);
        self.received[to] += c;
        self.sent[from] += c;
        self.received_bytes[to] += sparse_bytes(nnz, tail);
        self.sent_bytes[from] += sparse_bytes(nnz, tail);
        self.messages += 1;
    }

    /// Account a compressed (`COMP`) payload: `nnz` quantized support
    /// pairs in DOUBLEs, plus the compressor's declared bytes-on-wire.
    pub fn send_comp(&mut self, from: usize, to: usize, nnz: usize, bytes: u64) {
        self.assert_edge(from, to);
        let c = self.cost.comp_cost(nnz);
        self.received[to] += c;
        self.sent[from] += c;
        self.received_bytes[to] += bytes as f64;
        self.sent_bytes[from] += bytes as f64;
        self.messages += 1;
    }

    /// Dense broadcast to all neighbors (the per-round exchange of every
    /// dense-communication method). Accumulation order matches per-edge
    /// [`Network::send_dense`] calls exactly (repeated `+= c`, not `c *
    /// degree`), so broadcast and unicast accounting stay bit-identical.
    pub fn broadcast_dense(&mut self, from: usize, len: usize) {
        let c = self.cost.dense_cost(len);
        for &to in &self.topo.adj[from] {
            self.received[to] += c;
            self.sent[from] += c;
            self.received_bytes[to] += dense_bytes(len);
            self.sent_bytes[from] += dense_bytes(len);
            self.messages += 1;
        }
    }

    /// All nodes exchange dense iterates with all neighbors: the standard
    /// round of EXTRA / DSA / dense DSBA. One call = one round.
    pub fn round_dense_exchange(&mut self, len: usize) {
        for n in 0..self.topo.n {
            self.broadcast_dense(n, len);
        }
    }

    /// `C_n^t` for one node.
    pub fn received_by(&self, n: usize) -> f64 {
        self.received[n]
    }

    /// DOUBLEs sent by one node so far.
    pub fn sent_by(&self, n: usize) -> f64 {
        self.sent[n]
    }

    /// The paper's `C_max^t = max_n C_n^t`.
    pub fn max_received(&self) -> f64 {
        self.received.iter().copied().fold(0.0, f64::max)
    }

    /// Total doubles moved (sum over receivers).
    pub fn total_received(&self) -> f64 {
        self.received.iter().sum()
    }

    /// Bytes-on-wire received by one node so far.
    pub fn bytes_received_by(&self, n: usize) -> f64 {
        self.received_bytes[n]
    }

    /// Bytes-on-wire sent by one node so far.
    pub fn bytes_sent_by(&self, n: usize) -> f64 {
        self.sent_bytes[n]
    }

    /// Byte analog of [`Network::max_received`].
    pub fn max_received_bytes(&self) -> f64 {
        self.received_bytes.iter().copied().fold(0.0, f64::max)
    }

    /// Total bytes moved (sum over receivers).
    pub fn total_received_bytes(&self) -> f64 {
        self.received_bytes.iter().sum()
    }

    pub fn messages(&self) -> u64 {
        self.messages
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_round_costs_degree_times_d() {
        let topo = Topology::star(5); // center degree 4, leaves degree 1
        let mut net = Network::new(topo, CommCostModel::values_only());
        net.round_dense_exchange(100);
        // center receives from 4 leaves
        assert_eq!(net.received_by(0), 400.0);
        // each leaf receives only from the center
        assert_eq!(net.received_by(3), 100.0);
        assert_eq!(net.max_received(), 400.0);
    }

    #[test]
    fn sparse_cheaper_than_dense_when_sparse() {
        let cost = CommCostModel::default();
        assert!(cost.sparse_cost(10, 0) < cost.dense_cost(1000));
        // crossover: with pair cost 2, sparse wins iff nnz < d/2 (mod header)
        assert!(cost.sparse_cost(600, 0) > cost.dense_cost(1000));
    }

    #[test]
    #[should_panic(expected = "not an edge")]
    #[cfg(debug_assertions)]
    fn non_edge_send_panics_in_debug() {
        let topo = Topology::path(4); // 0-1-2-3
        let mut net = Network::new(topo, CommCostModel::default());
        net.send_dense(0, 3, 10);
    }

    #[test]
    fn byte_accounting_tracks_payload_kinds() {
        let topo = Topology::path(3); // 0-1-2
        let cost = CommCostModel::default();
        let mut net = Network::new(topo, cost);
        net.send_dense(0, 1, 10);
        assert_eq!(net.bytes_received_by(1), 80.0);
        net.send_sparse(1, 2, 3, 1);
        assert_eq!(net.bytes_received_by(2), 44.0);
        net.send_comp(2, 1, 4, 48);
        assert_eq!(net.bytes_received_by(1), 128.0);
        assert_eq!(net.received_by(1), cost.dense_cost(10) + cost.comp_cost(4));
        assert_eq!(net.max_received_bytes(), 128.0);
        assert_eq!(net.total_received_bytes(), 80.0 + 44.0 + 48.0);
        assert_eq!(net.bytes_sent_by(0), 80.0);
        assert_eq!(net.bytes_sent_by(2), 48.0);
    }

    #[test]
    fn accounting_is_symmetric_in_total() {
        let topo = Topology::ring(6);
        let mut net = Network::new(topo, CommCostModel::default());
        net.round_dense_exchange(50);
        let sent: f64 = (0..6).map(|n| net.sent[n]).sum();
        assert_eq!(sent, net.total_received());
        assert_eq!(net.messages(), 12); // 6 nodes x 2 neighbors
    }
}
