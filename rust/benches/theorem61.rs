//! Theorem 6.1 — empirical contraction of the Lyapunov function H^t
//! against the proven bound `1 - min{gamma/12, mu/48L, 1/3q, 1/4}` at the
//! theorem's step size alpha = 1/(24 L).
//!
//!     cargo bench --bench theorem61

use dsba::algorithms::{AlgoParams, Algorithm, Dsba};
use dsba::bench_harness::header;
use dsba::comm::{CommCostModel, Network};
use dsba::coordinator::{solve_optimum, LyapunovProbe};
use dsba::graph::MixingMatrix;
use dsba::prelude::*;
use std::sync::Arc;

fn main() {
    header("Theorem 6.1: Lyapunov contraction, alpha = 1/(24 L)");
    println!(
        "{:>10} {:>10} {:>12} {:>14} {:>14}",
        "topology", "lambda", "bound(1-r)", "measured", "holds?"
    );
    for (tname, topo) in [
        ("er(0.4)", Topology::erdos_renyi(6, 0.4, 42)),
        ("ring", Topology::ring(6)),
        ("complete", Topology::complete(6)),
    ] {
        for lambda in [0.2, 0.05] {
            let ds = SyntheticSpec::tiny()
                .with_samples(180)
                .with_regression(true)
                .generate(13);
            let part = ds.partition_seeded(6, 2);
            let p: Arc<dyn Problem> = Arc::new(RidgeProblem::new(part, lambda));
            let mix = MixingMatrix::laplacian(&topo, 1.0);
            let z_star = solve_optimum(p.as_ref(), 1e-12);
            let mut probe = LyapunovProbe::new(p.clone(), &mix, z_star, 0.0);
            let alpha = probe.max_alpha();
            let params = AlgoParams::new(alpha, p.dim(), 17);
            let mut alg = Dsba::new(p.clone(), mix, topo.clone(), &params);
            let mut net = Network::new(topo.clone(), CommCostModel::default());
            let mut h = Vec::new();
            for _ in 0..60 * p.q() {
                alg.step(&mut net);
                h.push(probe.observe(&alg));
            }
            let t = h.len() as f64;
            let measured = (h.last().unwrap() / h[0]).powf(1.0 / t);
            let bound = 1.0 - probe.theoretical_rate();
            println!(
                "{tname:>10} {lambda:>10.2} {bound:>12.6} {measured:>14.6} {:>14}",
                if measured <= bound { "yes" } else { "VIOLATED" }
            );
        }
    }
    println!("(measured per-step contraction should be <= the bound — the\n theorem is conservative, so typically much smaller)");
}
