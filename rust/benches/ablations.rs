//! Ablations over the design choices DESIGN.md calls out:
//!   1. backward (resolvent) vs forward (DSA-style) delta evaluation at
//!      increasing step sizes — the stability story behind DSBA's rate;
//!   2. SAGA correction on/off (plain stochastic operator vs eq. (19));
//!   3. mixing construction: Laplacian (tau margins) vs lazy Metropolis;
//!   4. relay protocol vs dense broadcast at fixed iterates (comm only).
//!
//!     cargo bench --bench ablations

use dsba::algorithms::{AlgoParams, Algorithm, AlgorithmKind, Dsba, Dsa, NodeSaga};
use dsba::bench_harness::header;
use dsba::comm::{CommCostModel, Network};
use dsba::coordinator::Experiment;
use dsba::graph::MixingMatrix;
use dsba::prelude::*;
use dsba::util::rng::Rng;
use std::sync::Arc;

fn world() -> (dsba::data::Dataset, Topology) {
    let ds = SyntheticSpec::tiny()
        .with_samples(320)
        .with_regression(true)
        .generate(23);
    (ds, Topology::erdos_renyi(8, 0.4, 42))
}

/// Ablation 2 baseline: DSBA's resolvent update but with a *plain*
/// stochastic operator estimate (no SAGA table correction) — variance
/// does not vanish, so it stalls at a noise floor.
struct NoSagaDsba {
    problem: Arc<dyn Problem>,
    mix: MixingMatrix,
    topo: Topology,
    alpha: f64,
    z: Vec<Vec<f64>>,
    z_prev: Vec<Vec<f64>>,
    coef_prev: Vec<(usize, Vec<f64>)>,
    rngs: Vec<Rng>,
    t: usize,
}

impl NoSagaDsba {
    fn new(problem: Arc<dyn Problem>, mix: MixingMatrix, topo: Topology, params: &AlgoParams) -> Self {
        let n = problem.nodes();
        let z = vec![params.z0.clone(); n];
        let w = problem.coef_width();
        let mut root = Rng::new(params.seed);
        NoSagaDsba {
            alpha: params.alpha,
            z_prev: z.clone(),
            z,
            coef_prev: vec![(0, vec![0.0; w]); n],
            rngs: (0..n).map(|i| root.fork(i as u64)).collect(),
            t: 0,
            problem,
            mix,
            topo,
        }
    }

    fn step(&mut self) {
        let p = self.problem.as_ref();
        let (alpha, lam) = (self.alpha, p.lambda());
        let mut z_next = self.z.clone();
        for n in 0..p.nodes() {
            let i = self.rngs[n].below(p.q());
            let mut psi = vec![0.0; p.dim()];
            if self.t == 0 {
                psi.copy_from_slice(&self.z[n]);
            } else {
                self.mix.mix_row(n, &self.topo, &self.z, &self.z_prev, &mut psi);
                // forward part of the previous estimate re-added (no table)
                let (ip, ref cp) = self.coef_prev[n];
                p.scatter(n, ip, cp, alpha, &mut psi);
                dsba::linalg::axpy(alpha * lam, &self.z[n], &mut psi);
            }
            let mut coefs = vec![0.0; p.coef_width()];
            p.backward(n, i, alpha, &psi, &mut z_next[n], &mut coefs);
            self.coef_prev[n] = (i, coefs);
        }
        std::mem::swap(&mut self.z_prev, &mut self.z);
        self.z = z_next;
        self.t += 1;
    }
}

fn main() {
    let (ds, topo) = world();
    let part = ds.partition_seeded(8, 2);
    let problem = RidgeProblem::new(part, 0.02);
    let z_star = dsba::coordinator::solve_optimum(&problem, 1e-12);

    header("ablation 1: backward vs forward delta at increasing alpha (20 passes)");
    println!("{:>8} {:>14} {:>14}", "alpha", "DSBA(backward)", "DSA(forward)");
    for alpha in [0.25, 0.5, 1.0, 2.0, 4.0] {
        let mut subs = Vec::new();
        for kind in [AlgorithmKind::Dsba, AlgorithmKind::Dsa] {
            let part = ds.partition_seeded(8, 2);
            let mut exp =
                Experiment::builder(RidgeProblem::new(part, 0.02), topo.clone(), kind)
                    .step_size(alpha)
                    .passes(20.0)
                    .z_star(z_star.clone())
                    .build();
            let s = exp.run().last_suboptimality();
            subs.push(if s.is_finite() { format!("{s:>14.2e}") } else { format!("{:>14}", "diverged") });
        }
        println!("{alpha:>8.2} {} {}", subs[0], subs[1]);
    }
    println!("(backward steps remain stable at alphas where forward steps blow up)");

    header("ablation 2: SAGA correction on/off (DSBA resolvent core, alpha = 0.5)");
    {
        let part = ds.partition_seeded(8, 2);
        let p: Arc<dyn Problem> = Arc::new(RidgeProblem::new(part, 0.02));
        let mix = MixingMatrix::laplacian(&topo, 1.0);
        let params = AlgoParams::new(0.5, p.dim(), 31);
        let mut with_saga = Dsba::new(p.clone(), mix.clone(), topo.clone(), &params);
        let mut without = NoSagaDsba::new(p.clone(), mix, topo.clone(), &params);
        let mut net = Network::new(topo.clone(), CommCostModel::default());
        for _ in 0..40 * p.q() {
            with_saga.step(&mut net);
            without.step();
        }
        let s1 = dsba::metrics::suboptimality(with_saga.iterates(), &z_star);
        let s2 = dsba::metrics::suboptimality(&without.z, &z_star);
        println!("with SAGA: {s1:.3e}   without: {s2:.3e}   (variance floor without the table)");
    }

    header("ablation 3: mixing construction (DSBA, 30 passes)");
    for (name, mix) in [
        ("laplacian tau=1.0x", MixingMatrix::laplacian(&topo, 1.0)),
        ("laplacian tau=1.5x", MixingMatrix::laplacian(&topo, 1.5)),
        ("laplacian tau=3.0x", MixingMatrix::laplacian(&topo, 3.0)),
        ("lazy metropolis", MixingMatrix::metropolis(&topo)),
    ] {
        let part = ds.partition_seeded(8, 2);
        let mut exp = Experiment::builder(
            RidgeProblem::new(part, 0.02),
            topo.clone(),
            AlgorithmKind::Dsba,
        )
        .step_size(1.0)
        .passes(30.0)
        .z_star(z_star.clone())
        .mixing(mix.clone())
        .build();
        let s = exp.run().last_suboptimality();
        println!("{name:>20}: kappa_g {:>7.1} -> suboptimality {s:.3e}", mix.kappa_g);
    }
    println!("(larger tau margins slow consensus: kappa_g grows, rate drops — the tau >= lambda_max(L) choice is the right one)");

    header("ablation 4: SAGA table init cost amortization");
    {
        // DSBA pays a one-time O(q) table init; measure it against the
        // per-iteration cost to justify the SAGA trade
        let part = ds.partition_seeded(8, 2);
        let p: Arc<dyn Problem> = Arc::new(RidgeProblem::new(part, 0.02));
        let z0 = vec![0.0; p.dim()];
        let t = std::time::Instant::now();
        let _tables: Vec<NodeSaga> =
            (0..p.nodes()).map(|n| NodeSaga::init(p.as_ref(), n, &z0)).collect();
        let init = t.elapsed().as_secs_f64();
        let mix = MixingMatrix::laplacian(&topo, 1.0);
        let params = AlgoParams::new(0.5, p.dim(), 37);
        let mut alg = Dsa::new(p.clone(), mix, topo.clone(), &params);
        let mut net = Network::new(topo.clone(), CommCostModel::default());
        let t = std::time::Instant::now();
        for _ in 0..200 {
            alg.step(&mut net);
        }
        let per_iter = t.elapsed().as_secs_f64() / 200.0;
        println!(
            "table init {:.3} ms  ~=  {:.1} iterations of steady-state work",
            init * 1e3,
            init / per_iter
        );
    }
}
