//! Transport overhead: DOUBLEs/sec moved by the parallel engine with
//! in-process mpsc edges vs per-edge loopback TCP sockets (every payload
//! serialized through the wire codec and length-prefix-framed). Output is
//! identical either way (transport parity, `rust/tests/engine_parity.rs`);
//! only wall-clock changes — this table is the honest price of making the
//! paper's `C_n^t` DOUBLEs cross real sockets.
//!
//!     cargo bench --bench transport_overhead

use dsba::algorithms::{AlgoParams, AlgorithmKind};
use dsba::bench_harness::header;
use dsba::comm::{CommCostModel, CompressionSpec, Network};
use dsba::graph::MixingMatrix;
use dsba::prelude::*;
use dsba::runtime::{LocalTransport, ParallelEngine, TcpTransport};
use dsba::util::json::Json;
use dsba::util::timer::Timer;
use std::sync::Arc;

/// Run `rounds` engine rounds; returns (secs, DOUBLEs accounted).
fn time_rounds(eng: &mut ParallelEngine, topo: &Topology, rounds: usize) -> (f64, f64) {
    let mut net = Network::new(topo.clone(), CommCostModel::default());
    // warm past t = 0 special cases and relay pipeline fill
    for _ in 0..topo.diameter + 2 {
        eng.step(&mut net);
    }
    let warm = net.total_received();
    let t = Timer::start();
    for _ in 0..rounds {
        eng.step(&mut net);
    }
    (t.secs(), net.total_received() - warm)
}

/// Same loop, also tracking declared bytes-on-wire (the COMP-frame sizes
/// the cost model charges, 8 B/coordinate dense).
fn time_rounds_bytes(eng: &mut ParallelEngine, topo: &Topology, rounds: usize) -> (f64, f64, f64) {
    let mut net = Network::new(topo.clone(), CommCostModel::default());
    for _ in 0..topo.diameter + 2 {
        eng.step(&mut net);
    }
    let (warm_d, warm_b) = (net.total_received(), net.total_received_bytes());
    let t = Timer::start();
    for _ in 0..rounds {
        eng.step(&mut net);
    }
    (
        t.secs(),
        net.total_received() - warm_d,
        net.total_received_bytes() - warm_b,
    )
}

fn main() {
    let threads = 4;
    for &nodes in &[8] {
        let topo = Topology::erdos_renyi(nodes, 0.4, 42);
        let ds = SyntheticSpec::rcv1_like()
            .with_samples(40 * nodes)
            .with_dim(4_096)
            .with_regression(true)
            .generate(3);
        let mix = MixingMatrix::laplacian(&topo, 1.0);
        header(&format!(
            "transport overhead @ N = {nodes} (d = 4096, {} edges, x{threads} threads)",
            topo.edge_count()
        ));
        println!(
            "{:>9} {:>9} {:>12} {:>14} {:>9}",
            "method", "transport", "per-round", "MDOUBLEs/sec", "overhead"
        );
        // a dense broadcast method and the sparse relay extreme
        for (kind, alpha, rounds) in [
            (AlgorithmKind::Dsba, 0.5, 30),
            (AlgorithmKind::Extra, 0.3, 20),
            (AlgorithmKind::DsbaSparse, 0.5, 30),
        ] {
            let problem: Arc<dyn Problem> =
                Arc::new(RidgeProblem::new(ds.partition_seeded(nodes, 2), 0.01));
            let params = AlgoParams::new(alpha, problem.dim(), 7);
            let mut rows = Vec::new();
            {
                let mut eng =
                    ParallelEngine::new(kind, problem.clone(), &mix, &topo, &params, threads);
                let (secs, doubles) = time_rounds(&mut eng, &topo, rounds);
                rows.push(("local", secs / rounds as f64, doubles / secs / 1e6));
            }
            {
                let transport = TcpTransport::loopback(&topo, params.seed)
                    .expect("loopback transport setup");
                let mut eng = ParallelEngine::new_with_transport(
                    kind,
                    problem.clone(),
                    &mix,
                    &topo,
                    &params,
                    threads,
                    Box::new(transport),
                );
                let (secs, doubles) = time_rounds(&mut eng, &topo, rounds);
                rows.push(("tcp", secs / rounds as f64, doubles / secs / 1e6));
            }
            let local_rate = rows[0].2;
            for (name, per_round, rate) in rows {
                println!(
                    "{:>9} {:>9} {:>9.3} ms {:>14.2} {:>8.2}x",
                    kind.name(),
                    name,
                    per_round * 1e3,
                    rate,
                    local_rate / rate
                );
            }
        }
    }
    println!(
        "\n(overhead = local rate / tcp rate; the tcp column pays encode + \
         frame + loopback syscalls per edge, the real cross-process cost)"
    );

    compression_sweep();
}

/// Sweep `--compress` settings on a dense-broadcast method and record the
/// declared bytes-on-wire vs DOUBLEs for each, emitting
/// `results/BENCH_transport.json` — the repo's first machine-readable
/// transport snapshot.
fn compression_sweep() {
    let nodes = 8;
    let rounds = 20;
    let topo = Topology::erdos_renyi(nodes, 0.4, 42);
    let ds = SyntheticSpec::rcv1_like()
        .with_samples(40 * nodes)
        .with_dim(4_096)
        .with_regression(true)
        .generate(3);
    let mix = MixingMatrix::laplacian(&topo, 1.0);
    let problem: Arc<dyn Problem> =
        Arc::new(RidgeProblem::new(ds.partition_seeded(nodes, 2), 0.01));
    let d = problem.dim();
    let params = AlgoParams::new(0.3, d, 7);

    header(&format!(
        "compression sweep: extra @ N = {nodes} (d = {d}, local transport, {rounds} rounds)"
    ));
    println!(
        "{:>10} {:>14} {:>16} {:>9} {:>9}",
        "compress", "DOUBLEs", "bytes-on-wire", "ratio", "ms/round"
    );

    let specs = [
        CompressionSpec::None,
        CompressionSpec::TopK(d / 64),
        CompressionSpec::TopK(d / 16),
        CompressionSpec::TopK(d / 4),
        CompressionSpec::Qsgd(64),
    ];
    let mut dense_bytes = 0.0_f64;
    let mut sweep = Vec::new();
    for spec in &specs {
        let mut eng = ParallelEngine::new_full(
            AlgorithmKind::Extra,
            problem.clone(),
            &mix,
            &topo,
            &params,
            4,
            Box::new(LocalTransport::new(topo.n)),
            spec,
        );
        let (secs, doubles, bytes) = time_rounds_bytes(&mut eng, &topo, rounds);
        if *spec == CompressionSpec::None {
            dense_bytes = bytes;
        }
        let ratio = if dense_bytes > 0.0 {
            bytes / dense_bytes
        } else {
            1.0
        };
        println!(
            "{:>10} {:>14.0} {:>16.0} {:>9.3} {:>9.3}",
            spec.name(),
            doubles,
            bytes,
            ratio,
            secs / rounds as f64 * 1e3
        );
        sweep.push(Json::from_pairs(vec![
            ("compress", Json::Str(spec.name())),
            ("rounds", Json::Num(rounds as f64)),
            ("doubles", Json::Num(doubles)),
            ("bytes_on_wire", Json::Num(bytes)),
            ("secs", Json::Num(secs)),
            ("rounds_per_sec", Json::Num(rounds as f64 / secs)),
        ]));
    }

    let doc = Json::from_pairs(vec![
        ("bench", Json::Str("transport".into())),
        ("method", Json::Str("extra".into())),
        ("nodes", Json::Num(nodes as f64)),
        ("dim", Json::Num(d as f64)),
        ("sweep", Json::Arr(sweep)),
    ]);
    std::fs::create_dir_all("results").expect("create results dir");
    std::fs::write("results/BENCH_transport.json", doc.to_string())
        .expect("write BENCH_transport.json");
    println!(
        "\n(ratio = bytes-on-wire / dense bytes at matched rounds; snapshot \
         written to results/BENCH_transport.json)"
    );
}
