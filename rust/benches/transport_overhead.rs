//! Transport overhead: DOUBLEs/sec moved by the parallel engine with
//! in-process mpsc edges vs per-edge loopback TCP sockets (every payload
//! serialized through the wire codec and length-prefix-framed). Output is
//! identical either way (transport parity, `rust/tests/engine_parity.rs`);
//! only wall-clock changes — this table is the honest price of making the
//! paper's `C_n^t` DOUBLEs cross real sockets.
//!
//!     cargo bench --bench transport_overhead

use dsba::algorithms::{AlgoParams, AlgorithmKind};
use dsba::bench_harness::header;
use dsba::comm::{CommCostModel, Network};
use dsba::graph::MixingMatrix;
use dsba::prelude::*;
use dsba::runtime::{ParallelEngine, TcpTransport};
use dsba::util::timer::Timer;
use std::sync::Arc;

/// Run `rounds` engine rounds; returns (secs, DOUBLEs accounted).
fn time_rounds(eng: &mut ParallelEngine, topo: &Topology, rounds: usize) -> (f64, f64) {
    let mut net = Network::new(topo.clone(), CommCostModel::default());
    // warm past t = 0 special cases and relay pipeline fill
    for _ in 0..topo.diameter + 2 {
        eng.step(&mut net);
    }
    let warm = net.total_received();
    let t = Timer::start();
    for _ in 0..rounds {
        eng.step(&mut net);
    }
    (t.secs(), net.total_received() - warm)
}

fn main() {
    let threads = 4;
    for &nodes in &[8] {
        let topo = Topology::erdos_renyi(nodes, 0.4, 42);
        let ds = SyntheticSpec::rcv1_like()
            .with_samples(40 * nodes)
            .with_dim(4_096)
            .with_regression(true)
            .generate(3);
        let mix = MixingMatrix::laplacian(&topo, 1.0);
        header(&format!(
            "transport overhead @ N = {nodes} (d = 4096, {} edges, x{threads} threads)",
            topo.edge_count()
        ));
        println!(
            "{:>9} {:>9} {:>12} {:>14} {:>9}",
            "method", "transport", "per-round", "MDOUBLEs/sec", "overhead"
        );
        // a dense broadcast method and the sparse relay extreme
        for (kind, alpha, rounds) in [
            (AlgorithmKind::Dsba, 0.5, 30),
            (AlgorithmKind::Extra, 0.3, 20),
            (AlgorithmKind::DsbaSparse, 0.5, 30),
        ] {
            let problem: Arc<dyn Problem> =
                Arc::new(RidgeProblem::new(ds.partition_seeded(nodes, 2), 0.01));
            let params = AlgoParams::new(alpha, problem.dim(), 7);
            let mut rows = Vec::new();
            {
                let mut eng =
                    ParallelEngine::new(kind, problem.clone(), &mix, &topo, &params, threads);
                let (secs, doubles) = time_rounds(&mut eng, &topo, rounds);
                rows.push(("local", secs / rounds as f64, doubles / secs / 1e6));
            }
            {
                let transport = TcpTransport::loopback(&topo, params.seed)
                    .expect("loopback transport setup");
                let mut eng = ParallelEngine::new_with_transport(
                    kind,
                    problem.clone(),
                    &mix,
                    &topo,
                    &params,
                    threads,
                    Box::new(transport),
                );
                let (secs, doubles) = time_rounds(&mut eng, &topo, rounds);
                rows.push(("tcp", secs / rounds as f64, doubles / secs / 1e6));
            }
            let local_rate = rows[0].2;
            for (name, per_round, rate) in rows {
                println!(
                    "{:>9} {:>9} {:>9.3} ms {:>14.2} {:>8.2}x",
                    kind.name(),
                    name,
                    per_round * 1e3,
                    rate,
                    local_rate / rate
                );
            }
        }
    }
    println!(
        "\n(overhead = local rate / tcp rate; the tcp column pays encode + \
         frame + loopback syscalls per edge, the real cross-process cost)"
    );
}
