//! §5.1 communication claims: per-iteration DOUBLEs of DSBA-s vs dense
//! DSBA across a sparsity sweep, locating the crossover that Table 1
//! predicts at rho ~ Delta(G)/(2N).
//!
//!     cargo bench --bench sparse_comm

use dsba::algorithms::{AlgoParams, Algorithm, Dsba, DsbaSparse};
use dsba::bench_harness::header;
use dsba::comm::{CommCostModel, Network};
use dsba::graph::MixingMatrix;
use dsba::prelude::*;
use std::sync::Arc;

fn main() {
    let nodes = 10;
    let topo = Topology::erdos_renyi(nodes, 0.4, 42);
    let delta_g = topo.max_degree();
    header("sparse-communication sweep (values-only cost model)");
    println!(
        "N = {nodes}, Delta(G) = {delta_g}; predicted crossover at rho ~ Delta/(2N) = {:.3}",
        delta_g as f64 / (2.0 * nodes as f64)
    );
    println!(
        "{:>8} {:>14} {:>14} {:>8}",
        "rho", "dense dbl/it", "sparse dbl/it", "ratio"
    );
    let d = 4096;
    for rho in [0.001, 0.003, 0.01, 0.03, 0.1, 0.2, 0.4] {
        let ds = SyntheticSpec::tiny()
            .with_samples(400)
            .with_dim(d)
            .with_density(rho)
            .with_regression(true)
            .generate(9);
        let part = ds.partition_seeded(nodes, 2);
        let p: Arc<dyn Problem> = Arc::new(RidgeProblem::new(part, 0.01));
        let mix = MixingMatrix::laplacian(&topo, 1.0);
        let params = AlgoParams::new(0.5, p.dim(), 11);
        let mut dense = Dsba::new(p.clone(), mix.clone(), topo.clone(), &params);
        let mut sparse = DsbaSparse::new(p.clone(), mix, topo.clone(), &params);
        // values-only pricing matches the paper's O() statements
        let mut net_d = Network::new(topo.clone(), CommCostModel::values_only());
        let mut net_s = Network::new(topo.clone(), CommCostModel::values_only());
        let rounds = 80;
        for _ in 0..rounds {
            dense.step(&mut net_d);
            sparse.step(&mut net_s);
        }
        let dd = net_d.max_received() / rounds as f64;
        let ss = net_s.max_received() / rounds as f64;
        println!("{rho:>8.3} {dd:>14.0} {ss:>14.0} {:>8.3}", ss / dd);
    }
    println!(
        "(ratio < 1 while data is sparse; crossover appears as rho approaches \
         the Delta/2N prediction — pipeline fill and the one-time phibar \
         flood amortize over the run)"
    );
}
