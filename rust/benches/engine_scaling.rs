//! Parallel-engine scaling: wall-clock per round of the sequential
//! reference driver vs the multi-threaded engine at several worker
//! counts, across node counts — the speedup table behind the runtime's
//! "fast path" claim. Output is identical either way (engine parity), so
//! only time changes.
//!
//!     cargo bench --bench engine_scaling

use dsba::algorithms::{build, AlgoParams, AlgorithmKind};
use dsba::bench_harness::header;
use dsba::comm::{CommCostModel, Network};
use dsba::graph::MixingMatrix;
use dsba::prelude::*;
use dsba::runtime::{engine::auto_threads, ParallelEngine};
use dsba::util::timer::Timer;
use std::sync::Arc;

fn time_rounds(alg: &mut dyn dsba::algorithms::Algorithm, topo: &Topology, rounds: usize) -> f64 {
    let mut net = Network::new(topo.clone(), CommCostModel::default());
    // warm past t=0 special cases and relay pipeline fill
    for _ in 0..topo.diameter + 2 {
        alg.step(&mut net);
    }
    let t = Timer::start();
    for _ in 0..rounds {
        alg.step(&mut net);
    }
    t.secs() / rounds as f64
}

fn main() {
    let cores = auto_threads(usize::MAX);
    println!("host: {cores} core(s) available");
    let mut thread_grid: Vec<usize> = vec![2];
    if cores >= 4 {
        thread_grid.push(4);
    }
    if cores > 4 {
        thread_grid.push(cores);
    }
    thread_grid.dedup();

    for &nodes in &[8, 16] {
        let topo = Topology::erdos_renyi(nodes, 0.4, 42);
        let ds = SyntheticSpec::rcv1_like()
            .with_samples(40 * nodes)
            .with_dim(8_192)
            .with_regression(true)
            .generate(3);
        let mix = MixingMatrix::laplacian(&topo, 1.0);
        header(&format!(
            "engine scaling @ N = {nodes} (d = 8192, q = {})",
            40 * nodes / nodes
        ));
        println!(
            "{:>9} {:>8} {:>14} {:>10}",
            "method", "engine", "per-round", "speedup"
        );
        // dense methods dominated by per-node O(q rho d + deg d) work —
        // the regime the acceptance criterion targets — plus the sparse
        // relay as the communication-heavy extreme
        for (kind, alpha, rounds) in [
            (AlgorithmKind::Dsba, 0.5, 40),
            (AlgorithmKind::Extra, 0.3, 25),
            (AlgorithmKind::DsbaSparse, 0.5, 40),
        ] {
            let problem: Arc<dyn Problem> = Arc::new(RidgeProblem::new(
                ds.partition_seeded(nodes, 2),
                0.01,
            ));
            let params = AlgoParams::new(alpha, problem.dim(), 7);
            let mut seq = build(kind, problem.clone(), &mix, &topo, &params);
            let t_seq = time_rounds(seq.as_mut(), &topo, rounds);
            println!(
                "{:>9} {:>8} {:>11.3} ms {:>10}",
                kind.name(),
                "seq",
                t_seq * 1e3,
                "1.00x"
            );
            for &threads in &thread_grid {
                let mut par =
                    ParallelEngine::new(kind, problem.clone(), &mix, &topo, &params, threads);
                let t_par = time_rounds(&mut par, &topo, rounds);
                println!(
                    "{:>9} {:>5} x{} {:>11.3} ms {:>9.2}x",
                    kind.name(),
                    "par",
                    threads,
                    t_par * 1e3,
                    t_seq / t_par
                );
            }
        }
    }
    println!(
        "\n(speedup > 1x expected for dense methods at N >= 8; the sparse \
         relay has lighter per-node compute, so it saturates earlier)"
    );
}
