//! Parallel-engine scaling: wall-clock per round of the sequential
//! reference driver vs the multi-threaded engine at several worker
//! counts, across node counts — the speedup table behind the runtime's
//! "fast path" claim. Output is identical either way (engine parity), so
//! only time changes.
//!
//! A second sweep compares the two round clocks (`sync` vs `async:1`,
//! `async:2`) at 8 and 16 nodes, and a third measures the overhead of
//! the per-round telemetry stream (writer on vs off — the wait-free
//! `emit` should make it disappear in the noise). Both sweeps land in
//! the machine-readable snapshot `results/BENCH_engine.json`
//! (wall-clock and rounds/sec per cell).
//!
//!     cargo bench --bench engine_scaling

use dsba::algorithms::{build, AlgoParams, AlgorithmKind};
use dsba::bench_harness::header;
use dsba::comm::{CommCostModel, Network};
use dsba::graph::MixingMatrix;
use dsba::prelude::*;
use dsba::runtime::{engine::auto_threads, ParallelEngine};
use dsba::util::timer::Timer;
use std::sync::Arc;

fn time_rounds(alg: &mut dyn dsba::algorithms::Algorithm, topo: &Topology, rounds: usize) -> f64 {
    let mut net = Network::new(topo.clone(), CommCostModel::default());
    // warm past t=0 special cases and relay pipeline fill
    for _ in 0..topo.diameter + 2 {
        alg.step(&mut net);
    }
    let t = Timer::start();
    for _ in 0..rounds {
        alg.step(&mut net);
    }
    t.secs() / rounds as f64
}

fn main() {
    let cores = auto_threads(usize::MAX);
    println!("host: {cores} core(s) available");
    let mut thread_grid: Vec<usize> = vec![2];
    if cores >= 4 {
        thread_grid.push(4);
    }
    if cores > 4 {
        thread_grid.push(cores);
    }
    thread_grid.dedup();

    for &nodes in &[8, 16] {
        let topo = Topology::erdos_renyi(nodes, 0.4, 42);
        let ds = SyntheticSpec::rcv1_like()
            .with_samples(40 * nodes)
            .with_dim(8_192)
            .with_regression(true)
            .generate(3);
        let mix = MixingMatrix::laplacian(&topo, 1.0);
        header(&format!(
            "engine scaling @ N = {nodes} (d = 8192, q = {})",
            40 * nodes / nodes
        ));
        println!(
            "{:>9} {:>8} {:>14} {:>10}",
            "method", "engine", "per-round", "speedup"
        );
        // dense methods dominated by per-node O(q rho d + deg d) work —
        // the regime the acceptance criterion targets — plus the sparse
        // relay as the communication-heavy extreme
        for (kind, alpha, rounds) in [
            (AlgorithmKind::Dsba, 0.5, 40),
            (AlgorithmKind::Extra, 0.3, 25),
            (AlgorithmKind::DsbaSparse, 0.5, 40),
        ] {
            let problem: Arc<dyn Problem> = Arc::new(RidgeProblem::new(
                ds.partition_seeded(nodes, 2),
                0.01,
            ));
            let params = AlgoParams::new(alpha, problem.dim(), 7);
            let mut seq = build(kind, problem.clone(), &mix, &topo, &params);
            let t_seq = time_rounds(seq.as_mut(), &topo, rounds);
            println!(
                "{:>9} {:>8} {:>11.3} ms {:>10}",
                kind.name(),
                "seq",
                t_seq * 1e3,
                "1.00x"
            );
            for &threads in &thread_grid {
                let mut par =
                    ParallelEngine::new(kind, problem.clone(), &mix, &topo, &params, threads);
                let t_par = time_rounds(&mut par, &topo, rounds);
                println!(
                    "{:>9} {:>5} x{} {:>11.3} ms {:>9.2}x",
                    kind.name(),
                    "par",
                    threads,
                    t_par * 1e3,
                    t_seq / t_par
                );
            }
        }
    }
    println!(
        "\n(speedup > 1x expected for dense methods at N >= 8; the sparse \
         relay has lighter per-node compute, so it saturates earlier)"
    );

    mode_sweep();
}

/// Round-clock sweep: the barrier-synced clock vs the bounded-staleness
/// async clock at small windows, DSBA on the dense broadcast path. Async
/// wins wall-clock only when per-node round times are uneven (stragglers,
/// NUMA, shared cores); on an idle host the cells should be close —
/// that's the point of snapshotting them.
fn mode_sweep() {
    use dsba::comm::CompressionSpec;
    use dsba::runtime::{LocalTransport, ModeSpec};
    use dsba::util::json::Json;

    let threads = 4;
    let rounds = 40usize;
    let mut sweep = Vec::new();
    for &nodes in &[8, 16] {
        let topo = Topology::erdos_renyi(nodes, 0.4, 42);
        let ds = SyntheticSpec::rcv1_like()
            .with_samples(40 * nodes)
            .with_dim(8_192)
            .with_regression(true)
            .generate(3);
        let mix = MixingMatrix::laplacian(&topo, 1.0);
        let problem: Arc<dyn Problem> =
            Arc::new(RidgeProblem::new(ds.partition_seeded(nodes, 2), 0.01));
        let params = AlgoParams::new(0.5, problem.dim(), 7);
        header(&format!(
            "round clocks @ N = {nodes} (dsba, d = 8192, x{threads} threads, local transport)"
        ));
        println!(
            "{:>9} {:>12} {:>12} {:>14} {:>8}",
            "mode", "per-round", "rounds/sec", "max staleness", "stalls"
        );
        for mode in [ModeSpec::Sync, ModeSpec::Async(1), ModeSpec::Async(2)] {
            let mut eng = ParallelEngine::new_full_mode(
                AlgorithmKind::Dsba,
                problem.clone(),
                &mix,
                &topo,
                &params,
                threads,
                Box::new(LocalTransport::new(topo.n)),
                &CompressionSpec::None,
                mode,
            );
            let secs = time_rounds(&mut eng, &topo, rounds) * rounds as f64;
            let (max_staleness, stalls) = eng.staleness_stats();
            println!(
                "{:>9} {:>9.3} ms {:>12.1} {:>14} {:>8}",
                mode.name(),
                secs / rounds as f64 * 1e3,
                rounds as f64 / secs,
                max_staleness,
                stalls
            );
            sweep.push(Json::from_pairs(vec![
                ("nodes", Json::Num(nodes as f64)),
                ("mode", Json::Str(mode.name())),
                ("rounds", Json::Num(rounds as f64)),
                ("secs", Json::Num(secs)),
                ("rounds_per_sec", Json::Num(rounds as f64 / secs)),
                ("max_staleness", Json::Num(max_staleness as f64)),
                ("stalls", Json::Num(stalls as f64)),
            ]));
        }
    }
    let doc = Json::from_pairs(vec![
        ("bench", Json::Str("engine".into())),
        ("method", Json::Str("dsba".into())),
        ("dim", Json::Num(8_192.0)),
        ("threads", Json::Num(threads as f64)),
        ("sweep", Json::Arr(sweep)),
        ("telemetry_overhead", Json::Arr(telemetry_overhead())),
    ]);
    std::fs::create_dir_all("results").expect("create results dir");
    std::fs::write("results/BENCH_engine.json", doc.to_string())
        .expect("write BENCH_engine.json");
    println!("\n(snapshot written to results/BENCH_engine.json)");
}

/// Telemetry-overhead cells: the same DSBA workload with the per-round
/// JSONL stream off vs on, under both round clocks. Workers hand rows to
/// the writer thread via a wait-free bounded channel; the async clock
/// additionally emits a round-admitted control-plane event per node per
/// round when telemetry is on, so its on-cell prices event emission on
/// top of the phase spans. Every on-cell should sit within noise of its
/// off-cell — this snapshot is the receipt.
fn telemetry_overhead() -> Vec<dsba::util::json::Json> {
    use dsba::comm::CompressionSpec;
    use dsba::runtime::{LocalTransport, ModeSpec};
    use dsba::util::json::Json;

    let (nodes, threads, rounds) = (8usize, 4usize, 40usize);
    let topo = Topology::erdos_renyi(nodes, 0.4, 42);
    let ds = SyntheticSpec::rcv1_like()
        .with_samples(40 * nodes)
        .with_dim(8_192)
        .with_regression(true)
        .generate(3);
    let mix = MixingMatrix::laplacian(&topo, 1.0);
    let problem: Arc<dyn Problem> =
        Arc::new(RidgeProblem::new(ds.partition_seeded(nodes, 2), 0.01));
    let params = AlgoParams::new(0.5, problem.dim(), 7);

    let run = |mode: ModeSpec, telemetry: &TelemetrySpec| {
        let mut eng = ParallelEngine::new_faulted(
            AlgorithmKind::Dsba,
            problem.clone(),
            &mix,
            &topo,
            &params,
            threads,
            Box::new(LocalTransport::new(topo.n)),
            &CompressionSpec::None,
            mode,
            &FaultSpec::none(),
            telemetry,
        )
        .expect("bench engine builds");
        time_rounds(&mut eng, &topo, rounds)
    };
    std::fs::create_dir_all("results").expect("create results dir");
    let scratch = "results/bench_telemetry_scratch.jsonl";
    let mut cells = Vec::new();
    // async:0 paces rounds identically to sync but goes through the
    // admission path, whose on-cell emits a round-admitted event per
    // node per round — the events-on overhead measurement
    for mode in [ModeSpec::Sync, ModeSpec::Async(0)] {
        let off = run(mode, &TelemetrySpec::disabled());
        let on = run(mode, &TelemetrySpec::to_path(scratch));
        let _ = std::fs::remove_file(scratch);

        header(&format!(
            "telemetry overhead @ N = {nodes} (dsba, d = 8192, x{threads} threads, {})",
            mode.name()
        ));
        println!("{:>10} {:>12} {:>12}", "telemetry", "per-round", "overhead");
        println!("{:>10} {:>9.3} ms {:>12}", "off", off * 1e3, "—");
        println!(
            "{:>10} {:>9.3} ms {:>11.1}%",
            "on",
            on * 1e3,
            (on / off - 1.0) * 100.0
        );
        // pin the recording cost: with telemetry on, every round
        // additionally runs the span timers (a handful of Instant reads
        // per node), the wait-free row emit, and — under the async clock
        // — one control-plane event per admission. 3x + 2ms absolute
        // slack sits far above scheduler noise yet catches a hot-path
        // regression — and span/event code leaking into the
        // telemetry-off path would instead inflate the off cell against
        // the committed snapshot via bench-compare.
        assert!(
            on <= off * 3.0 + 0.002,
            "telemetry + span/event overhead out of bounds ({}): \
             off {off:.6}s, on {on:.6}s per round",
            mode.name()
        );
        cells.extend([("off", off), ("on", on)].into_iter().map(|(label, secs)| {
            Json::from_pairs(vec![
                ("mode", Json::Str(mode.name())),
                ("telemetry", Json::Str(label.into())),
                ("nodes", Json::Num(nodes as f64)),
                ("rounds", Json::Num(rounds as f64)),
                ("per_round_secs", Json::Num(secs)),
                ("overhead_pct", Json::Num((secs / off - 1.0) * 100.0)),
            ])
        }));
    }
    cells
}
