//! Table 1 — empirical validation of the convergence-rate and cost
//! entries:
//!   (a) iterations-to-epsilon scaling in kappa (vary lambda),
//!       kappa_g (vary topology), and q (vary shard size);
//!   (b) measured per-iteration communication DOUBLEs per method vs the
//!       O(Delta d) / O(N rho d) columns.
//!
//!     cargo bench --bench table1_costs [-- fast]

use dsba::algorithms::AlgorithmKind;
use dsba::bench_harness::header;
use dsba::comm::{CommCostModel, Network};
use dsba::coordinator::Experiment;
use dsba::graph::MixingMatrix;
use dsba::prelude::*;
use std::sync::Arc;

fn passes_to_tol(
    ds: &dsba::data::Dataset,
    topo: &Topology,
    nodes: usize,
    lambda: f64,
    kind: AlgorithmKind,
    alpha: f64,
    tol: f64,
    max_passes: f64,
) -> f64 {
    let part = ds.partition_seeded(nodes, 2);
    let problem = RidgeProblem::new(part, lambda);
    let z_star = dsba::coordinator::solve_optimum(&problem, tol * 1e-3);
    let mut exp = Experiment::builder(problem, topo.clone(), kind)
        .step_size(alpha)
        .passes(max_passes)
        .record_points(400)
        .z_star(z_star)
        .build();
    let trace = exp.run();
    trace.passes_to_tol(tol).unwrap_or(f64::NAN)
}

fn main() {
    let fast = std::env::args().any(|a| a == "fast");
    let (samples, reps) = if fast { (240, 1) } else { (480, 1) };
    let tol = 1e-9;
    let nodes = 8;

    header("Table 1(a): passes-to-1e-9 vs condition number kappa (DSBA vs DSA vs EXTRA)");
    println!("{:>10} {:>8} {:>8} {:>8}", "lambda", "DSBA", "DSA", "EXTRA");
    let ds = SyntheticSpec::tiny()
        .with_samples(samples)
        .with_regression(true)
        .generate(5);
    let topo = Topology::erdos_renyi(nodes, 0.4, 42);
    for lambda in [0.1, 0.01, 0.001] {
        let mut row = format!("{lambda:>10.0e}");
        for (kind, alpha) in [
            (AlgorithmKind::Dsba, 1.0),
            (AlgorithmKind::Dsa, 0.25),
            (AlgorithmKind::Extra, 0.45),
        ] {
            let mut total = 0.0;
            for _ in 0..reps {
                total +=
                    passes_to_tol(&ds, &topo, nodes, lambda, kind, alpha, tol, 4000.0);
            }
            row += &format!(" {:>8.1}", total / reps as f64);
        }
        println!("{row}");
    }
    println!("(expected: EXTRA's kappa^2 rate degrades fastest as lambda shrinks)");

    header("Table 1(a): passes-to-1e-9 vs graph condition number kappa_g (DSBA)");
    println!("{:>10} {:>10} {:>8}", "topology", "kappa_g", "DSBA");
    for (name, topo) in [
        ("complete", Topology::complete(nodes)),
        ("er(0.4)", Topology::erdos_renyi(nodes, 0.4, 42)),
        ("grid", Topology::grid2d(nodes)),
        ("ring", Topology::ring(nodes)),
    ] {
        let mix = MixingMatrix::laplacian(&topo, 1.0);
        let p = passes_to_tol(&ds, &topo, nodes, 0.05, AlgorithmKind::Dsba, 1.0, tol, 4000.0);
        println!("{name:>10} {:>10.1} {p:>8.1}", mix.kappa_g);
    }

    header("Table 1(a): passes-to-1e-9 vs local sample count q (DSBA)");
    println!("{:>6} {:>8}", "q", "DSBA");
    for q_total in [nodes * 20, nodes * 60, nodes * 120] {
        let ds = SyntheticSpec::tiny()
            .with_samples(q_total)
            .with_regression(true)
            .generate(6);
        let p = passes_to_tol(&ds, &topo, nodes, 0.05, AlgorithmKind::Dsba, 1.0, tol, 4000.0);
        println!("{:>6} {p:>8.1}", q_total / nodes);
    }

    header("Table 1(b): measured communication DOUBLEs per iteration");
    let ds = SyntheticSpec::rcv1_like()
        .with_samples(400)
        .with_dim(4096)
        .with_regression(true)
        .generate(7);
    let part = ds.partition_seeded(nodes, 2);
    let rho = part.max_shard_density();
    let d = part.dim;
    let topo = Topology::erdos_renyi(nodes, 0.4, 42);
    let delta_g = topo.max_degree();
    println!(
        "d = {d}, rho = {rho:.2e}, Delta(G) = {delta_g}, N = {nodes} \
         => dense bound Delta*d = {}, sparse bound ~2*N*rho*d = {:.0}",
        delta_g * d,
        2.0 * nodes as f64 * rho * d as f64
    );
    println!("{:>9} {:>14} {:>18}", "method", "dbl/iter", "vs dense bound");
    for kind in [
        AlgorithmKind::Dsba,
        AlgorithmKind::DsbaSparse,
        AlgorithmKind::Dsa,
        AlgorithmKind::Extra,
    ] {
        let part = ds.partition_seeded(nodes, 2);
        let problem: Arc<dyn Problem> = Arc::new(RidgeProblem::new(part, 0.01));
        let mix = MixingMatrix::laplacian(&topo, 1.0);
        let params = dsba::algorithms::AlgoParams::new(0.5, problem.dim(), 3);
        let mut alg = dsba::algorithms::build(kind, problem, &mix, &topo, &params);
        let mut net = Network::new(topo.clone(), CommCostModel::values_only());
        let rounds = 60;
        for _ in 0..rounds {
            alg.step(&mut net);
        }
        let per_iter = net.max_received() / rounds as f64;
        println!(
            "{:>9} {per_iter:>14.0} {:>17.2}x",
            kind.name(),
            per_iter / (delta_g * d) as f64
        );
    }
}
