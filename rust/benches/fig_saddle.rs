//! Saddle-workload figure bench: the two minimax registry entries
//! (robust-ls, dro-bilinear) under DSBA / DSBA-s / DSA / EXTRA, printing
//! the saddle-residual series against passes and C_max DOUBLEs — the
//! fig3-style panels generalized from the AUC statistic to the generic
//! saddle merit. The residual must shrink geometrically under DSBA
//! (Theorem 6.1's monotone-operator statement covers saddle operators);
//! `rust/tests/saddle.rs` pins that on CI-sized configs.
//!
//!     cargo bench --bench fig_saddle [-- fast]

use dsba::algorithms::AlgorithmKind;
use dsba::bench_harness::{summarize, write_results, FigureSpec, ScoreStat};

fn main() {
    let fast = std::env::args().any(|a| a == "fast");
    for problem in ["robust-ls", "dro-bilinear"] {
        let mut spec = FigureSpec::defaults(problem);
        spec.title = match problem {
            "robust-ls" => "Saddle figure: robust least squares",
            _ => "Saddle figure: DRO bilinear margin game",
        };
        spec.methods = vec![
            AlgorithmKind::Dsba,
            AlgorithmKind::DsbaSparse,
            AlgorithmKind::Dsa,
            AlgorithmKind::Extra,
        ];
        spec.samples = 300;
        spec.dim = 1024;
        spec.passes = 10.0;
        if fast {
            spec.datasets = vec!["sector-like"];
            spec.passes = 4.0;
            spec.samples = 200;
            spec.dim = 512;
        }
        let runs = spec.run();
        summarize(&runs, ScoreStat::SaddleResidual);
        write_results(&format!("fig_saddle_{problem}"), &runs);
        for (ds, m, t) in &runs {
            let first = t.rows.first().map(|r| r.saddle_res).unwrap_or(f64::NAN);
            println!(
                "[{ds}] {} saddle residual {:.3e} -> {:.3e}",
                m.name(),
                first,
                t.last_saddle_res()
            );
        }
    }
}
