//! Figure 3 — l2-relaxed AUC maximization: AUC vs passes and vs C_max
//! DOUBLEs. Per §7.3 only DSBA / DSA / EXTRA are compared (SSDA does not
//! apply to the saddle operator; DLM does not converge on it).
//!
//!     cargo bench --bench fig3_auc [-- fast]

use dsba::algorithms::AlgorithmKind;
use dsba::bench_harness::{summarize, write_results, FigureSpec, ScoreStat};

fn main() {
    let fast = std::env::args().any(|a| a == "fast");
    let mut spec = FigureSpec::defaults("auc");
    spec.title = "Figure 3: AUC maximization";
    spec.methods = vec![
        AlgorithmKind::Dsba,
        AlgorithmKind::Dsa,
        AlgorithmKind::Extra,
    ];
    if fast {
        spec.datasets = vec!["sector-like"];
        spec.passes = 6.0;
        spec.samples = 300;
        spec.dim = 1024;
    }
    let runs = spec.run();
    summarize(&runs, ScoreStat::Auc);
    write_results("fig3_auc", &runs);

    for (ds, m, t) in &runs {
        println!("[{ds}] {} final AUC {:.4}", m.name(), t.last_auc());
    }
}
