//! Figure 2 — Logistic Regression: same grid as Figure 1 with the
//! 1-D-Newton resolvent (appendix §9.6) on the DSBA side.
//!
//!     cargo bench --bench fig2_logistic [-- fast]

use dsba::bench_harness::{summarize, write_results, FigureSpec, ScoreStat};

fn main() {
    let fast = std::env::args().any(|a| a == "fast");
    let mut spec = FigureSpec::defaults("logistic");
    spec.title = "Figure 2: Logistic Regression";
    if fast {
        spec.datasets = vec!["rcv1-like"];
        spec.passes = 8.0;
        spec.samples = 300;
        spec.dim = 1024;
    }
    let runs = spec.run();
    summarize(&runs, ScoreStat::Suboptimality);
    write_results("fig2_logistic", &runs);
}
