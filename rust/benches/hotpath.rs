//! Hot-path microbenchmarks (the §Perf working set): per-iteration cost
//! of each algorithm, the sparse primitives underneath them, and the XLA
//! artifact execution path. Self-contained timing harness (criterion is
//! not vendored) via util::timer::measure.
//!
//!     cargo bench --bench hotpath

use dsba::algorithms::{build, AlgoParams, AlgorithmKind};
use dsba::bench_harness::header;
use dsba::comm::{CommCostModel, Network};
use dsba::graph::MixingMatrix;
use dsba::prelude::*;
use dsba::util::timer::measure;
use std::sync::Arc;

fn main() {
    let nodes = 10;
    let topo = Topology::erdos_renyi(nodes, 0.4, 42);
    let ds = SyntheticSpec::rcv1_like()
        .with_samples(2_000)
        .with_dim(8_192)
        .with_regression(true)
        .generate(3);
    let part = ds.partition_seeded(nodes, 2);
    let rho = part.max_shard_density();
    let d = part.dim;
    println!("workload: N={nodes}, q={}, d={d}, rho={rho:.2e}", part.q);

    header("sparse primitives");
    let shard = part.shards[0].clone();
    let mut z = vec![0.5; d];
    let st = measure(
        || {
            for i in 0..64 {
                std::hint::black_box(shard.row_dot(i, &z));
            }
        },
        0.3,
        20,
    );
    println!("row_dot x64 (nnz~{:.0}): {}", rho * d as f64, st.display());
    let st = measure(
        || {
            for i in 0..64 {
                shard.row_axpy(i, 1e-9, &mut z);
            }
        },
        0.3,
        20,
    );
    println!("row_axpy x64: {}", st.display());
    let st = measure(
        || {
            std::hint::black_box(shard.matvec(&z));
        },
        0.3,
        10,
    );
    println!("full shard matvec (q={}): {}", shard.rows, st.display());

    header("per-round cost by algorithm (one synchronous network round)");
    for (kind, alpha) in [
        (AlgorithmKind::Dsba, 1.0),
        (AlgorithmKind::DsbaSparse, 1.0),
        (AlgorithmKind::Dsa, 0.3),
        (AlgorithmKind::Extra, 0.4),
        (AlgorithmKind::Dlm, 0.0),
        (AlgorithmKind::Dgd, 0.3),
    ] {
        let part = ds.partition_seeded(nodes, 2);
        let problem: Arc<dyn Problem> = Arc::new(RidgeProblem::new(part, 0.01));
        let mix = MixingMatrix::laplacian(&topo, 1.0);
        let params = AlgoParams::new(alpha, problem.dim(), 7);
        let mut alg = build(kind, problem, &mix, &topo, &params);
        let mut net = Network::new(topo.clone(), CommCostModel::default());
        // warm up past the t=0 special case and relay pipeline fill
        for _ in 0..topo.diameter + 2 {
            alg.step(&mut net);
        }
        let st = measure(|| alg.step(&mut net), 0.5, 10);
        println!("{:>9}: {}", kind.name(), st.display());
    }

    header("XLA artifact path (PJRT CPU, dense-padded shard)");
    match dsba::runtime::XlaRuntime::load_default() {
        Ok(rt) if !rt.has_backend() => {
            println!("skipped (manifest OK, PJRT backend not compiled in)")
        }
        Ok(rt) => {
            let shard = &part.shards[0];
            let y = &part.labels[0];
            let zz = vec![0.1; d];
            // first call compiles; time it separately
            let t = std::time::Instant::now();
            let _ = rt.full_op_ridge(shard, &zz, y).unwrap();
            println!("first call (compile + exec): {:.1} ms", t.elapsed().as_secs_f64() * 1e3);
            let st = measure(
                || {
                    std::hint::black_box(rt.full_op_ridge(shard, &zz, y).unwrap());
                },
                1.0,
                5,
            );
            println!("full_op_ridge steady-state: {}", st.display());
            let st = measure(
                || {
                    std::hint::black_box(rt.coefs_ridge(shard, &zz, y).unwrap());
                },
                1.0,
                5,
            );
            println!("coefs_ridge steady-state: {}", st.display());
        }
        Err(e) => println!("skipped ({e})"),
    }
}
