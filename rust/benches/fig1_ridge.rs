//! Figure 1 — Ridge Regression: suboptimality vs effective passes and vs
//! C_max DOUBLEs on the three dataset profiles, for DSBA / DSA / EXTRA /
//! SSDA / DLM.
//!
//!     cargo bench --bench fig1_ridge          (full grid)
//!     cargo bench --bench fig1_ridge -- fast  (single dataset, short)

use dsba::bench_harness::{summarize, write_results, FigureSpec, ScoreStat};

fn main() {
    let fast = std::env::args().any(|a| a == "fast");
    let mut spec = FigureSpec::defaults("ridge");
    spec.title = "Figure 1: Ridge Regression";
    if fast {
        spec.datasets = vec!["rcv1-like"];
        spec.passes = 8.0;
        spec.samples = 300;
        spec.dim = 1024;
    }
    let runs = spec.run();
    summarize(&runs, ScoreStat::Suboptimality);
    write_results("fig1_ridge", &runs);

    // shape check mirrored from the paper: stochastic methods dominate
    // per pass, and DSBA dominates DSA, on every dataset
    for ds in &spec.datasets {
        let get = |name: &str| {
            runs.iter()
                .find(|(d, m, _)| d == ds && m.name() == name)
                .map(|(_, _, t)| t.last_suboptimality())
        };
        if let (Some(dsba), Some(dsa), Some(extra)) =
            (get("DSBA"), get("DSA"), get("EXTRA"))
        {
            println!(
                "[{ds}] DSBA {dsba:.2e} | DSA {dsa:.2e} | EXTRA {extra:.2e} -> {}",
                if dsba <= dsa && dsa <= extra * 10.0 {
                    "paper ordering holds"
                } else {
                    "ORDERING DEVIATES (check tuning)"
                }
            );
        }
    }
}
