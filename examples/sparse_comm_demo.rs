//! The §5.1 sparse-communication scheme in action: DSBA vs DSBA-s on the
//! same seeds produce bit-identical learning curves while moving an order
//! of magnitude less data on sparse datasets.
//!
//!     cargo run --release --example sparse_comm_demo

use dsba::algorithms::{AlgoParams, Algorithm, Dsba, DsbaSparse};
use dsba::comm::{CommCostModel, Network};
use dsba::graph::MixingMatrix;
use dsba::prelude::*;
use std::sync::Arc;

fn main() {
    let topo = Topology::erdos_renyi(10, 0.4, 42);
    println!("graph: diameter {} max degree {}", topo.diameter, topo.max_degree());
    println!(
        "\n{:>9} | {:>13} | {:>13} | {:>8} | {:>10}",
        "rho", "dense dbl/it", "sparse dbl/it", "ratio", "drift"
    );
    for rho in [0.001, 0.005, 0.02, 0.1, 0.3] {
        let ds = SyntheticSpec::rcv1_like()
            .with_samples(500)
            .with_dim(4_096)
            .with_density(rho)
            .with_regression(true)
            .generate(3);
        let part = ds.partition(10);
        let lambda = 1.0 / (10.0 * part.total_samples() as f64);
        let p: Arc<dyn Problem> = Arc::new(RidgeProblem::new(part, lambda));
        let mix = MixingMatrix::laplacian(&topo, 1.0);
        let params = AlgoParams::new(1.0, p.dim(), 99);
        let mut dense = Dsba::new(p.clone(), mix.clone(), topo.clone(), &params);
        let mut sparse = DsbaSparse::new(p.clone(), mix, topo.clone(), &params);
        let mut net_d = Network::new(topo.clone(), CommCostModel::default());
        let mut net_s = Network::new(topo.clone(), CommCostModel::default());
        let rounds = 200;
        let mut drift: f64 = 0.0;
        for _ in 0..rounds {
            dense.step(&mut net_d);
            sparse.step(&mut net_s);
        }
        for n in 0..10 {
            drift = drift.max(dsba::linalg::dist2_sq(
                &dense.iterates()[n],
                &sparse.iterates()[n],
            ));
        }
        let d_per = net_d.max_received() / rounds as f64;
        let s_per = net_s.max_received() / rounds as f64;
        println!(
            "{rho:>9.3} | {d_per:>13.0} | {s_per:>13.0} | {:>8.3} | {drift:>10.1e}",
            s_per / d_per
        );
        assert!(drift < 1e-16, "DSBA-s must replicate DSBA exactly");
    }
    println!("\n(identical iterates; sparse wins while rho << Delta(G)/N, as Table 1 predicts)");
    println!("sparse_comm_demo OK");
}
