//! Decentralized AUC maximization (paper §3.2 / §7.3): the workload that
//! motivates the monotone-operator formulation — pairwise losses cannot
//! be decomposed across nodes, but the saddle reformulation (11)-(12)
//! can, and DSBA solves it with closed-form resolvents.
//!
//!     cargo run --release --example auc_maximization

use dsba::algorithms::AlgorithmKind;
use dsba::coordinator::Experiment;
use dsba::metrics::auc_score;
use dsba::prelude::*;

fn main() {
    // imbalanced sparse classification data (sector-like profile)
    let ds = SyntheticSpec::sector_like()
        .with_samples(800)
        .with_dim(2_048)
        .generate(11);
    let part = ds.partition(10);
    println!(
        "dataset: Q = {}, d = {}, positive ratio p = {:.3}",
        part.total_samples(),
        part.dim,
        part.positive_ratio
    );
    let lambda = 1.0 / (10.0 * part.total_samples() as f64);
    let topo = Topology::erdos_renyi(10, 0.4, 42);

    // AUC of the zero model is 0.5 by construction
    let baseline = auc_score(&part, &vec![0.0; part.dim + 3]);
    println!("AUC before training: {baseline:.4}");

    for (kind, alpha) in [
        (AlgorithmKind::Dsba, 0.5),
        (AlgorithmKind::Dsa, 0.05),
        (AlgorithmKind::Extra, 0.05),
    ] {
        let part = ds.partition(10);
        let mut exp = Experiment::builder(
            AucProblem::new(part, lambda),
            topo.clone(),
            kind,
        )
        .step_size(alpha)
        .passes(10.0)
        .record_points(8)
        .build();
        let trace = exp.run();
        println!(
            "{:>7}: AUC {:.4} after {:>5.1} passes | suboptimality {:.2e} | comm {:.2e} doubles",
            kind.name(),
            trace.last_auc(),
            trace.rows.last().unwrap().passes,
            trace.last_suboptimality(),
            trace.final_comm()
        );
    }
    println!("auc_maximization OK");
}
