//! Regenerate the paper's figures from the command line:
//!
//!     cargo run --release --example figures -- [1|2|3] [--fast]
//!
//! Figure 1: ridge regression; Figure 2: logistic regression;
//! Figure 3: l2-relaxed AUC maximization (DSBA / DSA / EXTRA only —
//! SSDA does not apply to the saddle operator and DLM diverges, §7.3).
//! Each run prints suboptimality (or AUC) against both effective passes
//! and C_max DOUBLEs — the two x-axes of every panel — and writes
//! results/figN.json.

use dsba::algorithms::AlgorithmKind;
use dsba::bench_harness::{summarize, write_results, FigureSpec};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let which = args.iter().find(|a| !a.starts_with("--")).cloned();
    let fast = args.iter().any(|a| a == "--fast");
    let run = |n: &str| {
        let (title, problem, methods): (_, _, Option<Vec<AlgorithmKind>>) = match n {
            "1" => ("Figure 1: Ridge Regression", "ridge", None),
            "2" => ("Figure 2: Logistic Regression", "logistic", None),
            "3" => (
                "Figure 3: AUC maximization",
                "auc",
                Some(vec![
                    AlgorithmKind::Dsba,
                    AlgorithmKind::Dsa,
                    AlgorithmKind::Extra,
                ]),
            ),
            other => {
                eprintln!("unknown figure {other}");
                std::process::exit(2);
            }
        };
        let mut spec = FigureSpec::defaults(problem);
        spec.title = title;
        if let Some(m) = methods {
            spec.methods = m;
        }
        if fast {
            spec.samples = 300;
            spec.dim = 1024;
            spec.passes = 8.0;
            spec.datasets = vec!["rcv1-like"];
        }
        let runs = spec.run();
        summarize(&runs, spec.score_stat());
        write_results(&format!("fig{n}"), &runs);
    };
    match which.as_deref() {
        Some(n) => run(n),
        None => {
            for n in ["1", "2", "3"] {
                run(n);
            }
        }
    }
}
