//! Quickstart: decentralized ridge regression with DSBA on 10 simulated
//! nodes, in ~30 lines of user code.
//!
//!     cargo run --release --example quickstart

use dsba::algorithms::AlgorithmKind;
use dsba::coordinator::Experiment;
use dsba::metrics::format_table;
use dsba::prelude::*;

fn main() {
    // 1. a sparse dataset (rcv1-like profile, CI-sized)
    let ds = SyntheticSpec::rcv1_like()
        .with_samples(1_000)
        .with_dim(2_048)
        .with_regression(true)
        .generate(7);
    println!(
        "dataset: Q = {}, d = {}, rho = {:.2e}",
        ds.samples(),
        ds.dim(),
        ds.density()
    );

    // 2. a 10-node Erdős–Rényi network (the paper's §7 setup)
    let topo = Topology::erdos_renyi(10, 0.4, 42);
    println!(
        "graph: diameter = {}, max degree = {}",
        topo.diameter,
        topo.max_degree()
    );

    // 3. the problem (see lambda note below)
    let part = ds.partition(10);
    // note: the paper's lambda = 1/(10 Q) makes kappa ~ 1e5 — at CI scale
    // that needs hundreds of passes (see EXPERIMENTS.md); the quickstart
    // uses a moderately conditioned lambda so deep tolerance is reached
    // in ~40 passes, matching the *shape* of Figure 1
    let lambda = 1e-3;
    let problem = RidgeProblem::new(part, lambda);

    // 4. run DSBA for 40 effective passes
    let mut exp = Experiment::builder(problem, topo, AlgorithmKind::Dsba)
        .step_size(2.0)
        .passes(40.0)
        .record_points(10)
        .build();
    let trace = exp.run();
    println!("{}", format_table(&trace.rows));
    println!(
        "final suboptimality {:.3e} after {:.1} passes, {:.2e} DOUBLEs on the hottest node",
        trace.last_suboptimality(),
        trace.rows.last().unwrap().passes,
        trace.final_comm()
    );
    assert!(trace.last_suboptimality() < 1e-6, "quickstart did not converge");
    println!("quickstart OK");
}
