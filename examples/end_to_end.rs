//! End-to-end system driver: exercises all three layers on a realistic
//! small workload and reports the paper's headline metrics.
//!
//!     make artifacts && cargo run --release --example end_to_end
//!
//! 1. generates an rcv1-profile sparse dataset (10,000 samples, d=8,192),
//! 2. builds the 10-node ER(0.4) network of §7,
//! 3. verifies the L1/L2 AOT artifacts (Pallas kernels lowered to HLO,
//!    loaded through PJRT) against the pure-Rust operators,
//! 4. pre-solves the ridge optimum, runs DSBA and DSBA-s to 15 effective
//!    passes, logging the convergence + communication curves,
//! 5. repeats on logistic regression and AUC maximization.
//!
//! The run is recorded in EXPERIMENTS.md §End-to-end.

use dsba::algorithms::AlgorithmKind;
use dsba::coordinator::Experiment;
use dsba::metrics::format_table;
use dsba::prelude::*;
use dsba::runtime::XlaRuntime;
use std::sync::Arc;

fn main() {
    let t0 = std::time::Instant::now();
    // ---- workload ----
    let ds = SyntheticSpec::rcv1_like()
        .with_samples(10_000)
        .with_dim(8_192)
        .with_regression(true)
        .generate(2024);
    let part = ds.partition(10);
    // lambda = 1e-3 (vs the paper's 1/(10 Q)) so the CI-scale run reaches
    // deep tolerance within 15 passes; the figure benches keep the paper
    // value and compare method *orderings* instead of absolute depth
    let lambda = 1e-3;
    println!(
        "[data] Q = {}, d = {}, rho = {:.2e}, q/node = {}, lambda = {:.2e}",
        part.total_samples(),
        part.dim,
        ds.density(),
        part.q,
        lambda
    );
    let topo = Topology::erdos_renyi(10, 0.4, 42);
    let mix = dsba::graph::MixingMatrix::laplacian(&topo, 1.0);
    println!(
        "[graph] ER(10, 0.4): diameter {}, max degree {}, gamma {:.4}, kappa_g {:.1}",
        topo.diameter,
        topo.max_degree(),
        mix.gamma,
        mix.kappa_g
    );

    // ---- layer check: XLA artifacts vs pure-Rust operators ----
    let ridge = Arc::new(RidgeProblem::new(part, lambda));
    match XlaRuntime::load_default() {
        Ok(rt) if !rt.has_backend() => {
            println!("[xla] SKIPPED (manifest OK, PJRT backend not compiled in)")
        }
        Ok(rt) => {
            let mut rng = Rng::new(1);
            let z: Vec<f64> = (0..ridge.dim()).map(|_| 0.1 * rng.normal()).collect();
            let shard = &ridge.partition().shards[0];
            let y = &ridge.partition().labels[0];
            let t = std::time::Instant::now();
            let xla = rt.full_op_ridge(shard, &z, y).expect("XLA exec");
            let xla_ms = t.elapsed().as_secs_f64() * 1e3;
            let mut rust = vec![0.0; ridge.dim()];
            let t = std::time::Instant::now();
            ridge.full_raw_mean(0, &z, &mut rust);
            let rust_ms = t.elapsed().as_secs_f64() * 1e3;
            let err = xla
                .iter()
                .zip(&rust)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0, f64::max);
            println!(
                "[xla] full-shard operator via PJRT: max |xla - rust| = {err:.2e} \
                 (xla {xla_ms:.1} ms dense-padded, rust {rust_ms:.3} ms sparse)"
            );
            assert!(err < 1e-6, "artifact mismatch");
        }
        Err(e) => println!("[xla] SKIPPED ({e})"),
    }

    // ---- ridge: DSBA vs DSBA-s vs DSA ----
    println!("\n[ridge] pre-solving optimum...");
    let z_star = dsba::coordinator::solve_optimum(ridge.as_ref(), 1e-10);
    println!(
        "[ridge] optimum residual {:.2e}",
        ridge.global_residual(&z_star)
    );
    for (kind, alpha) in [
        (AlgorithmKind::Dsba, 2.0),
        (AlgorithmKind::DsbaSparse, 2.0),
        (AlgorithmKind::Dsa, 0.3),
    ] {
        let mut exp = Experiment::builder_from_arc(ridge.clone(), topo.clone(), kind)
            .step_size(alpha)
            .passes(15.0)
            .record_points(6)
            .z_star(z_star.clone())
            .build();
        let trace = exp.run();
        println!("--- {} ---\n{}", kind.name(), format_table(&trace.rows));
    }

    // ---- logistic ----
    let ds_log = SyntheticSpec::rcv1_like()
        .with_samples(4_000)
        .with_dim(4_096)
        .generate(2025);
    let part_log = ds_log.partition(10);
    let lam_log = 1e-3;
    let mut exp = Experiment::builder(
        LogisticProblem::new(part_log, lam_log),
        topo.clone(),
        AlgorithmKind::Dsba,
    )
    .step_size(2.0)
    .passes(15.0)
    .record_points(6)
    .build();
    let trace = exp.run();
    println!("--- logistic / DSBA ---\n{}", format_table(&trace.rows));
    assert!(trace.last_suboptimality() < 1e-5, "logistic did not converge");

    // ---- AUC ----
    let ds_auc = SyntheticSpec::sector_like()
        .with_samples(3_000)
        .with_dim(4_096)
        .generate(2026);
    let part_auc = ds_auc.partition(10);
    let lam_auc = 1.0 / (10.0 * part_auc.total_samples() as f64);
    let mut exp = Experiment::builder(
        AucProblem::new(part_auc, lam_auc),
        topo,
        AlgorithmKind::Dsba,
    )
    .step_size(0.5)
    .passes(10.0)
    .record_points(6)
    .build();
    let trace = exp.run();
    println!("--- AUC / DSBA ---\n{}", format_table(&trace.rows));
    assert!(trace.last_auc() > 0.75, "AUC too low: {}", trace.last_auc());

    println!(
        "end_to_end OK in {:.1} s (all layers composed: data -> graph -> \
         XLA artifacts -> DSBA/DSBA-s/DSA -> metrics)",
        t0.elapsed().as_secs_f64()
    );
}
